"""Joint autoscaling: replica-pool engine semantics + the scale plane.

The engine-level contract (pinned here, relied on by the online
controller and the autoscale benchmark):

  * a replica pool bounds its function's *admission concurrency* —
    R invocations run at once, the rest queue FIFO;
  * warm-container pools shard per replica (a pool never serves more
    than R live containers) and cold starts are charged per replica
    spin-up;
  * a carried-in warm pool from an epoch with a larger R is trimmed to
    the R latest-expiring containers at load (the mid-sequence
    replica-change handoff);
  * an *ample* pool at zero provisioning price is **bit-identical** to
    ``scale=None`` on all four replay planes (fast / constrained /
    planned / serial) — the actuator is purely additive;
  * provisioned replica-seconds are billed, so scale-out is never free.

Plus the joint-search surface (:class:`ScaleSearcher` speaks the
``Searcher`` protocol; the grid plane serializes it explainably) and
the online control plane with the scale actuator enabled (ledger
conservation, payload shape, determinism, and the autoscale-off
bit-identity guard).
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.autoscale import (AutoscaleSpec, ScaleResult, ScaleSearcher,
                                  classify_saturation, grant_replicas,
                                  pool_capacity_factor)
from repro.core.backend import CallableBackend
from repro.core.campaign import PortfolioSpec, ReplaySpec
from repro.core.cost import PricingModel
from repro.core.dag import Workflow
from repro.core.engine import (ClusterModel, ColdStartModel, FleetEngine,
                               PoissonArrivals, ReplicaModel)
from repro.core.online import OnlineSpec, run_online
from repro.core.resources import ResourceConfig
from repro.core.search import make_searcher
from repro.serverless.generator import (DriftEvent, DriftSchedule,
                                        chain_workflow)
from repro.serverless.platform import SimulatedPlatform

# -- replica-pool engine semantics --------------------------------------

#: zero-price pools: semantics only, no replica-second billing
def _pool(replicas=None, default=1):
    return ReplicaModel(replicas=replicas or {}, default=default,
                        provision_frac=0.0, provision_floor=0.0)


def _one_fn():
    wf = Workflow("w")
    wf.add_function("f")
    return wf


def _unit_engine(**kw):
    """One function, exactly 1 s runtime — queueing is exact arithmetic."""
    return FleetEngine(CallableBackend(lambda node: 1.0), **kw)


def test_replica_pool_bounds_admission_concurrency():
    """R=1 serializes two simultaneous arrivals (the second waits a
    full service time); R=2 runs them concurrently."""
    r1 = _unit_engine(scale=_pool({"f": 1})).run(
        [_one_fn(), _one_fn()], [0.0, 0.0])
    assert r1.total_queue_delay == 1.0
    assert sorted(i.e2e for i in r1.instances) == [1.0, 2.0]

    r2 = _unit_engine(scale=_pool({"f": 2})).run(
        [_one_fn(), _one_fn()], [0.0, 0.0])
    assert r2.total_queue_delay == 0.0
    assert [i.e2e for i in r2.instances] == [1.0, 1.0]


def test_warm_pools_shard_per_replica_and_cold_charges_per_spinup():
    """R=1: the second arrival waits, then claims the first's warm
    container — ONE spin-up. R=2: both admitted cold — TWO spin-ups
    (each replica pays its own cold start), no queueing."""
    cold = ColdStartModel(delay_s=0.5, keep_alive_s=100.0)
    r1 = _unit_engine(cold_start=cold, scale=_pool({"f": 1})).run(
        [_one_fn(), _one_fn()], [0.0, 0.0])
    sat1 = r1.saturation()["w/f"]
    assert sat1["spinups"] == 1
    assert sorted(r1.cold_delays.tolist()) == [0.0, 0.5]
    assert r1.total_queue_delay == 1.5        # cold + service of inst 1

    r2 = _unit_engine(cold_start=cold, scale=_pool({"f": 2})).run(
        [_one_fn(), _one_fn()], [0.0, 0.0])
    sat2 = r2.saturation()["w/f"]
    assert sat2["spinups"] == 2
    assert r2.cold_delays.tolist() == [0.5, 0.5]
    assert r2.total_queue_delay == 0.0


def test_carry_handoff_trims_warm_pool_to_new_replica_count():
    """A warm pool carried from an R=3 epoch into an R=1 epoch is
    trimmed to the single latest-expiring container at load: the one
    arrival claims it (no spin-up) and the end-of-epoch carry holds
    exactly one container, not three."""
    cold = ColdStartModel(delay_s=0.5, keep_alive_s=1000.0)
    ep1 = _unit_engine(cold_start=cold, scale=_pool({"f": 3})).run(
        [_one_fn() for _ in range(3)], [0.0, 0.0, 0.0],
        collect_carry=True)
    assert len(ep1.carry.warm[("w", "f")]) == 3

    ep2 = _unit_engine(cold_start=cold, scale=_pool({"f": 1})).run(
        [_one_fn()], [10.0], carry=ep1.carry.pruned(10.0),
        collect_carry=True)
    assert ep2.cold_delays.tolist() == [0.0]          # claimed warm
    assert ep2.saturation()["w/f"]["spinups"] == 0
    assert len(ep2.carry.warm[("w", "f")]) == 1       # trimmed to R


def test_provisioned_replicas_are_billed_replica_seconds():
    """Scale-out is never free: the same fleet at R=2 with a non-zero
    provisioning price costs strictly more than unbounded serving, and
    a floor price adds on top."""
    base = _unit_engine().run([_one_fn(), _one_fn()], [0.0, 0.0])
    priced = _unit_engine(scale=ReplicaModel(
        replicas={"f": 2}, provision_frac=0.25)).run(
        [_one_fn(), _one_fn()], [0.0, 0.0])
    floored = _unit_engine(scale=ReplicaModel(
        replicas={"f": 2}, provision_frac=0.25, provision_floor=0.1)).run(
        [_one_fn(), _one_fn()], [0.0, 0.0])
    assert priced.total_cost > base.total_cost
    assert floored.total_cost > priced.total_cost


def test_saturation_reports_pool_diagnostics():
    """Satellite: per-function saturation rows carry the pool size,
    busy seconds, pool-relative utilization, and queue share."""
    rep = _unit_engine(scale=_pool({"f": 2})).run(
        [_one_fn() for _ in range(4)], [0.0] * 4)
    row = rep.saturation()["w/f"]
    assert row["replicas"] == 2
    assert row["busy_s"] == 4.0               # 4 invocations x 1 s
    assert row["utilization"] == pytest.approx(4.0 / (2 * rep.makespan))
    assert row["queue_share"] == 1.0          # the only queued function


# -- ample-pool bit-identity on all four replay planes ------------------

class _ScalarMirrorPricing(PricingModel):
    """Same numbers, no vectorized ``cost_batch``: forces the planned
    plane (mirrors the idiom pinned in test_replay_batch)."""

    def function_cost(self, runtime_s, config):
        return super().function_cost(runtime_s, config)


#: an admission bound no fleet here ever reaches + zero provisioning
#: price: the ReplicaModel must be a bit-exact no-op
_AMPLE = ReplicaModel(default=1_000_000, provision_frac=0.0,
                      provision_floor=0.0)


def _plane_engine(plane, scale):
    env = SimulatedPlatform().environment()
    if plane == "fast":
        return FleetEngine(env.backend, pricing=env.pricing, scale=scale)
    if plane == "constrained":
        return FleetEngine(env.backend, pricing=env.pricing, scale=scale,
                           cluster=ClusterModel(total_cpu=12.0,
                                                total_mem_mb=16384.0),
                           cold_start=ColdStartModel(delay_s=1.0,
                                                     keep_alive_s=30.0))
    if plane == "planned":
        return FleetEngine(env.backend, pricing=_ScalarMirrorPricing(),
                           scale=scale)
    assert plane == "serial"
    return FleetEngine(CallableBackend(lambda node: 2.0 / node.config.cpu),
                       pricing=env.pricing, scale=scale)


def _assert_reports_identical(got, want):
    assert np.array_equal(got.arrivals, want.arrivals)
    assert np.array_equal(got.finishes, want.finishes)
    assert np.array_equal(got.latencies, want.latencies)
    assert np.array_equal(got.queue_delays, want.queue_delays)
    assert np.array_equal(got.cold_delays, want.cold_delays)
    assert np.array_equal(got.costs, want.costs)
    assert got.makespan == want.makespan
    assert got.total_cost == want.total_cost
    assert got.queue_delay_by_function == want.queue_delay_by_function


@pytest.mark.parametrize("plane", ["fast", "constrained", "planned",
                                   "serial"])
def test_ample_pool_is_bit_identical_to_scale_none_on_every_plane(plane):
    """The acceptance bar: an ample zero-price ReplicaModel reproduces
    the pre-replica engine bit-for-bit on each replay plane. The
    with-scale engine routes through the event loop (replica bounds are
    an event-loop concept), so this is also a cross-plane check."""
    template = chain_workflow(4, seed=11)
    cands = [{n.name: ResourceConfig(cpu=float(c), mem=2048.0 * c)
              for n in template} for c in (2, 5)]
    seeds = [PoissonArrivals(1.0, 6, seed=s).times() for s in (0, 1)]
    base = _plane_engine(plane, None).run_many(template, cands, seeds)
    scaled = _plane_engine(plane, _AMPLE).run_many(template, cands, seeds)
    assert len(base) == len(scaled) == 4
    for got, want in zip(scaled, base):
        _assert_reports_identical(got, want)


def test_replica_pools_route_run_many_to_the_event_loop():
    """``batch_eligibility`` must name the replica bound as the reason
    a fast-plane replay lands on the constrained plane."""
    template = chain_workflow(3, seed=1)
    elig = _plane_engine("fast", _AMPLE).batch_eligibility(template, [])
    assert elig["plane"] == "constrained"
    assert any("replica pools" in r for r in elig["reasons"])


def test_replica_model_rejects_bad_pools():
    with pytest.raises(ValueError, match="must be >= 1"):
        ReplicaModel(replicas={"f": 0})
    with pytest.raises(ValueError, match="default pool"):
        ReplicaModel(default=0)
    with pytest.raises(ValueError, match="provision_frac"):
        ReplicaModel(provision_frac=-0.1)


# -- policy helpers -----------------------------------------------------

def test_classify_saturation_queue_share():
    sat = {"a/f": {"queue_delay_s": 3.0}, "a/g": {"queue_delay_s": 1.0}}
    bound, share = classify_saturation(sat, cold_delay_s=4.0)
    assert bound and share == pytest.approx(0.5)
    assert classify_saturation({}, 0.0) == (False, 0.0)
    # pure queueing, no cold component: fully capacity-attributed
    _, share = classify_saturation(sat, 0.0)
    assert share == 1.0


def test_grant_replicas_follows_critical_path_queue_delay():
    sat = {"w/f": {"queue_delay_s": 0.5}, "w/g": {"queue_delay_s": 2.0}}
    replicas = {"f": 1, "g": 1}
    grown = grant_replicas(replicas, sat, ["f", "g"], width=2,
                           max_replicas=2)
    assert grown == {"f": 2, "g": 2}          # g first (more queue), then f
    assert replicas == {"f": 1, "g": 1}       # input untouched (a copy)
    # every pool capped: the grant is a no-op
    assert grant_replicas(replicas, sat, ["f", "g"], width=2,
                          max_replicas=1) == replicas
    # off-path functions are a fallback once the path is capped
    sat2 = {"w/f": {"queue_delay_s": 0.0}, "w/h": {"queue_delay_s": 3.0}}
    assert grant_replicas({"f": 1, "h": 1}, sat2, ["f"], width=1,
                          max_replicas=4) == {"f": 1, "h": 2}


def test_pool_capacity_factor_tracks_provisioned_demand():
    base = ClusterModel(total_cpu=20.0, total_mem_mb=1e6)
    cfg = {"f": ResourceConfig(cpu=10.0, mem=1024.0)}
    # 4 replicas x 10 cpu = 40 cpu on a 20-cpu base -> x2
    assert pool_capacity_factor({"f": 4}, cfg, base,
                                max_scale=8.0) == pytest.approx(2.0)
    # never shrunk below the floor, always capped at max_scale
    assert pool_capacity_factor({"f": 4}, cfg, base, max_scale=8.0,
                                floor=3.0) == pytest.approx(3.0)
    assert pool_capacity_factor({"f": 4}, cfg, base,
                                max_scale=1.5) == pytest.approx(1.5)
    # an infinite base dimension needs no growth
    from repro.core.engine import INFINITE_CLUSTER
    assert pool_capacity_factor({"f": 100}, cfg, INFINITE_CLUSTER,
                                max_scale=8.0) == 1.0


def test_autoscale_spec_validation():
    with pytest.raises(ValueError, match="actuators"):
        AutoscaleSpec(actuators=("config", "warp"))
    with pytest.raises(ValueError, match="actuators"):
        AutoscaleSpec(actuators=())
    with pytest.raises(ValueError, match="max_replicas"):
        AutoscaleSpec(max_replicas=0)
    with pytest.raises(ValueError, match="deploy_utilization"):
        AutoscaleSpec(deploy_utilization=0.0)
    with pytest.raises(ValueError, match="max_cluster_scale"):
        AutoscaleSpec(max_cluster_scale=0.5)


# -- ScaleSearcher protocol ---------------------------------------------

_SEARCH_SPEC = AutoscaleSpec(rate=0.05, n_instances=12, max_rounds=4,
                             config_grant=4, max_replicas=4,
                             provision_frac=0.0)


def _search_once():
    env = SimulatedPlatform().environment()
    searcher = make_searcher("scale", env, spec=_SEARCH_SPEC)
    wf = chain_workflow(3, seed=2)
    return searcher, searcher.search(wf, 120.0), wf


def test_make_searcher_scale_lazy_registers():
    """``make_searcher("scale")`` resolves via the lazy autoscale
    import and refuses a self-referential inner searcher."""
    env = SimulatedPlatform().environment()
    s = make_searcher("scale", env)
    assert isinstance(s, ScaleSearcher) and s.name == "scale"
    with pytest.raises(ValueError, match="inner"):
        make_searcher("scale", env, inner="scale")


def test_scale_search_returns_joint_action():
    searcher, res, wf = _search_once()
    assert isinstance(res, ScaleResult)
    assert set(res.replicas) <= set(wf.nodes)
    assert all(1 <= r <= _SEARCH_SPEC.max_replicas
               for r in res.replicas.values())
    assert res.cluster_scale >= 1.0
    assert res.fleet_evals >= 1
    assert math.isfinite(res.fleet_cost)
    summary = res.summary()
    assert summary["total_replicas"] == sum(res.replicas.values())
    assert {"replicas", "cluster_scale", "fleet_attainment",
            "fleet_evals"} <= set(summary)
    # ~1.85 erlangs offered per R=1 pool: the loop must scale out
    assert sum(res.replicas.values()) > len(res.replicas)


def test_scale_resume_zero_budget_is_a_noop():
    searcher, res, _ = _search_once()
    assert res.state is not None
    assert res.state.payload["replicas"] == res.replicas
    assert searcher.resume(res.state, 0) is res


def test_grid_plane_serializes_scale_searcher_with_reason():
    """No plan(): the lockstep grid must serialize the joint searcher
    explainably, not silently."""
    from repro.core.gridsearch import grid_eligibility
    env = SimulatedPlatform().environment()
    searcher = make_searcher("scale", env, spec=_SEARCH_SPEC)
    (cell,) = grid_eligibility([(searcher, chain_workflow(3, seed=2),
                                 60.0)])
    assert not cell.eligible
    assert any("no plan()" in r for r in cell.reasons)


# -- online control plane with the scale actuator -----------------------

def _autoscale_spec(seed=0, **kw):
    """A small capacity-bound load step: deploy-sized pools saturate at
    3x rate, so the scale actuator must fire."""
    base = dict(
        portfolio=PortfolioSpec(n_workflows=2, size=4, kinds=("chain",),
                                slo_slacks=(1.6,)),
        replay=ReplaySpec(n_instances=12, rate=0.015,
                          cluster=ClusterModel(total_cpu=60.0,
                                               total_mem_mb=61440.0)),
        n_epochs=6,
        drift=DriftSchedule((DriftEvent(2, "load", 3.0),)),
        seed=seed, total_budget=256, cooldown_epochs=0,
        autoscale=AutoscaleSpec(provision_floor=0.02, max_replicas=8,
                                max_cluster_scale=6.0))
    base.update(kw)
    return OnlineSpec(**base)


def test_online_autoscale_ledger_is_conserved():
    report = run_online(_autoscale_spec())
    b = report.budget
    assert b["total"] == b["spent"] + b["remaining"]
    assert b["spent"] == sum(c.spent for c in report.cells)
    assert b["spent"] == sum(r.spent for r in report.reconfigs)


def test_online_autoscale_payload_exposes_pools():
    report = run_online(_autoscale_spec())
    payload = report.to_payload()
    for cell, row in zip(report.cells, payload["cells"]):
        assert cell.replicas is not None
        assert set(cell.replicas) == set(cell.task.template.nodes)
        assert row["replicas"] == sorted(cell.replicas.items())
        assert row["cluster_scale"] == cell.cluster_scale >= 1.0
    for row in payload["epochs"]:
        assert {"total_replicas", "cluster_scale"} <= set(row)
    # the load step forced scale-out past one-replica pools
    assert any(sum(c.replicas.values()) > len(c.replicas)
               for c in report.cells)
    assert any(r.accepted for r in report.reconfigs)


def test_online_autoscale_payload_is_deterministic():
    spec = _autoscale_spec(seed=7)
    assert run_online(spec).to_payload() == run_online(spec).to_payload()


def test_autoscale_off_keeps_payload_free_of_replica_keys():
    """The bit-identity guard: without an AutoscaleSpec no ReplicaModel
    exists and no replica key leaks into BENCH_online payloads."""
    spec = _autoscale_spec(autoscale=None)
    payload = run_online(spec).to_payload()
    for row in payload["cells"]:
        assert "replicas" not in row and "cluster_scale" not in row
    for row in payload["epochs"]:
        assert "total_replicas" not in row


def test_autoscale_bench_row_is_deterministic():
    """The emitted BENCH_autoscale.json row (minus wall-clock keys) is
    identical across runs and clears its pinned bars."""
    bench = pytest.importorskip(
        "benchmarks.autoscale",
        reason="benchmarks namespace needs the repo root on sys.path")
    first = bench.deterministic_payload(
        bench.autoscale_case("compound_shift", bench.COMPOUND_SHIFT))
    second = bench.deterministic_payload(
        bench.autoscale_case("compound_shift", bench.COMPOUND_SHIFT))
    assert first == second
    assert not any(k.endswith("_s") for k in first)
    assert bench.check_acceptance([first]) == []
