"""`invoke_config_batch` parity with scalar invocation, as properties.

The candidate-vectorized path (C configurations × N functions in one
numpy expression) is the campaign/adaptive hot path; these tests pin
that for random configs and topologies it is *exactly* a loop of
scalar ``invoke`` calls — on the deterministic analytic surface and,
under a fixed seed, on the stochastic surface too (the noise stream is
consumed in the same candidate-major order either way).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.env import ExecutionError
from repro.core.resources import ResourceConfig
from repro.serverless.generator import (chain_workflow, fan_workflow,
                                        layered_workflow)
from repro.serverless.platform import AnalyticBackend, StochasticBackend


def _build(kind, size, wf_seed):
    if kind == "chain":
        return chain_workflow(max(1, size), seed=wf_seed)
    if kind == "fan":
        return fan_workflow(max(1, size - 2), seed=wf_seed)
    return layered_workflow(max(2, size), n_layers=3, seed=wf_seed)


def _candidate_arrays(nodes, n_cand, rng, mem_lo, mem_hi):
    cpu = rng.uniform(0.5, 10.0, size=(n_cand, len(nodes)))
    mem = rng.uniform(mem_lo, mem_hi, size=(n_cand, len(nodes)))
    return cpu, mem


def _scalar_loop(backend, nodes, cpu, mem):
    """Candidate-major loop of scalar ``invoke`` calls; OOM-killed
    invocations report the clamped thrash runtime, like the batch."""
    n_cand = cpu.shape[0]
    runtimes = np.empty_like(cpu)
    failed = np.zeros(cpu.shape, dtype=bool)
    saved = [n.config for n in nodes]
    try:
        for ci in range(n_cand):
            for ni, node in enumerate(nodes):
                # assign raw values directly — the batch path consumes
                # unquantized arrays, so the constructor's lattice
                # snapping must not kick in here
                node.config = ResourceConfig()
                node.config.cpu = float(cpu[ci, ni])
                node.config.mem = float(mem[ci, ni])
                try:
                    runtimes[ci, ni] = backend.invoke(node)
                except ExecutionError:
                    runtimes[ci, ni] = backend.invoke_clamped(node)
                    failed[ci, ni] = True
    finally:
        for node, cfg in zip(nodes, saved):
            node.config = cfg
    return runtimes, failed


@given(st.sampled_from(["chain", "fan", "layered"]),
       st.integers(3, 10), st.integers(0, 10_000),
       st.integers(1, 12), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_analytic_config_batch_matches_scalar_invoke(kind, size, wf_seed,
                                                     n_cand, cfg_seed):
    """Analytic surface: batch == scalar loop, including OOM failures
    (batch reports the clamped thrash runtime scalar callers get from
    ``invoke_clamped``)."""
    wf = _build(kind, size, wf_seed)
    nodes = list(wf.nodes.values())
    rng = np.random.default_rng(cfg_seed)
    # range reaches below every profile's working-set floor, so OOM
    # rows genuinely occur across examples
    cpu, mem = _candidate_arrays(nodes, n_cand, rng, 64.0, 10240.0)
    backend = AnalyticBackend()
    got_rt, got_failed = backend.invoke_config_batch(nodes, cpu, mem)
    want_rt, want_failed = _scalar_loop(AnalyticBackend(), nodes, cpu, mem)
    assert np.array_equal(got_failed, want_failed)
    assert np.array_equal(got_rt, want_rt)


@given(st.sampled_from(["chain", "fan", "layered"]),
       st.integers(3, 8), st.integers(0, 10_000),
       st.integers(1, 8), st.integers(0, 10_000),
       st.floats(0.005, 0.1))
@settings(max_examples=25, deadline=None)
def test_stochastic_config_batch_matches_scalar_invoke(kind, size, wf_seed,
                                                       n_cand, cfg_seed,
                                                       sigma):
    """Stochastic surface under a fixed seed: the batched evaluation
    draws its (C, N) noise matrix in the same candidate-major order the
    scalar loop consumes one draw at a time, so results are identical.
    Configs stay above every working-set floor — a scalar OOM raises
    before its noise draw and would legitimately shift the stream."""
    wf = _build(kind, size, wf_seed)
    nodes = list(wf.nodes.values())
    rng = np.random.default_rng(cfg_seed)
    cpu, mem = _candidate_arrays(nodes, n_cand, rng, 6144.0, 10240.0)
    got_rt, got_failed = StochasticBackend(
        noise_sigma=sigma, seed=99).invoke_config_batch(nodes, cpu, mem)
    want_rt, want_failed = _scalar_loop(
        StochasticBackend(noise_sigma=sigma, seed=99), nodes, cpu, mem)
    assert not got_failed.any() and not want_failed.any()
    assert np.array_equal(got_rt, want_rt)


def test_stochastic_batch_charges_failures_deterministically():
    """Failing invocations are charged the deterministic clamped thrash
    time (noise applies to successful rows only)."""
    wf = chain_workflow(4, seed=3)
    nodes = list(wf.nodes.values())
    floors = np.array([n.payload.mem_floor for n in nodes])
    cpu = np.full((2, len(nodes)), 2.0)
    mem = np.tile(floors * 0.5, (2, 1))          # all OOM
    backend = StochasticBackend(noise_sigma=0.05, seed=1)
    runtimes, failed = backend.invoke_config_batch(nodes, cpu, mem)
    assert failed.all()
    clamped = np.empty(len(nodes))
    ref = AnalyticBackend()
    saved = [n.config for n in nodes]
    try:
        for ni, node in enumerate(nodes):
            node.config = ResourceConfig()
            node.config.cpu, node.config.mem = 2.0, float(mem[0, ni])
            clamped[ni] = ref.invoke_clamped(node)
    finally:
        for node, cfg in zip(nodes, saved):
            node.config = cfg
    assert np.allclose(runtimes, np.tile(clamped, (2, 1)))


@given(st.sampled_from(["chain", "fan", "layered"]),
       st.integers(3, 8), st.integers(0, 10_000),
       st.integers(1, 8), st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_stochastic_config_batch_matches_invoke_batch_rows(kind, size,
                                                           wf_seed, n_cand,
                                                           cfg_seed):
    """One (C, N) candidate plane consumes the noise stream exactly
    like C successive ``invoke_batch`` rows — *including* OOM rows
    (``invoke_batch`` draws noise for every position and discards the
    failing ones, so unlike the scalar-``invoke`` loop the stream
    positions line up even across failures). This is the property the
    fleet engine's batched replay paths rely on."""
    wf = _build(kind, size, wf_seed)
    nodes = list(wf.nodes.values())
    rng = np.random.default_rng(cfg_seed)
    # reaches below the working-set floors: OOM rows genuinely occur
    cpu, mem = _candidate_arrays(nodes, n_cand, rng, 64.0, 10240.0)
    got_rt, got_failed = StochasticBackend(
        noise_sigma=0.05, seed=7).invoke_config_batch(nodes, cpu, mem)
    row_backend = StochasticBackend(noise_sigma=0.05, seed=7)
    want_rt = np.empty_like(cpu)
    want_failed = np.zeros(cpu.shape, dtype=bool)
    saved = [n.config for n in nodes]
    try:
        for ci in range(n_cand):
            for ni, node in enumerate(nodes):
                node.config = ResourceConfig()
                node.config.cpu = float(cpu[ci, ni])
                node.config.mem = float(mem[ci, ni])
            want_rt[ci], want_failed[ci] = row_backend.invoke_batch(nodes)
    finally:
        for node, cfg in zip(nodes, saved):
            node.config = cfg
    assert np.array_equal(got_failed, want_failed)
    assert np.array_equal(got_rt, want_rt)


def test_backend_determinism_flags():
    """`deterministic` gates the fleet engine's vectorized replay
    plane: pure response surfaces opt in, stateful/opaque backends
    must not."""
    from repro.core.backend import BaseBackend, CallableBackend

    assert AnalyticBackend().deterministic
    assert not StochasticBackend().deterministic
    assert not BaseBackend.deterministic
    assert not CallableBackend(lambda node: 1.0).deterministic


def test_config_batch_leaves_node_configs_untouched():
    wf = fan_workflow(3, seed=0)
    nodes = list(wf.nodes.values())
    before = [(n.config.cpu, n.config.mem) for n in nodes]
    cpu = np.full((3, len(nodes)), 1.5)
    mem = np.full((3, len(nodes)), 4096.0)
    AnalyticBackend().invoke_config_batch(nodes, cpu, mem)
    assert [(n.config.cpu, n.config.mem) for n in nodes] == before
