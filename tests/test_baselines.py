"""BO / MAFF baselines + the paper's comparative claims (directional)."""
import pytest

from repro.core.baselines.bo import bo_search
from repro.core.baselines.maff import maff_search
from repro.core.scheduler import GraphCentricScheduler
from repro.serverless.platform import SimulatedPlatform
from repro.serverless.workloads import WORKLOADS, workload_slo


def run_all(name, bo_rounds=40):
    slo = workload_slo(name)
    out = {}
    env = SimulatedPlatform().environment()
    r = GraphCentricScheduler(env).schedule(WORKLOADS[name](), slo)
    out["aarc"] = (r.cost, env.trace.total_search_runtime,
                   env.trace.n_samples)
    env = SimulatedPlatform().environment()
    best = maff_search(WORKLOADS[name](), slo, env)
    out["maff"] = (best.cost, env.trace.total_search_runtime,
                   env.trace.n_samples)
    env = SimulatedPlatform().environment()
    best = bo_search(WORKLOADS[name](), slo, env, n_rounds=bo_rounds)
    out["bo"] = (best.cost if best else float("inf"),
                 env.trace.total_search_runtime, env.trace.n_samples)
    return out


@pytest.mark.parametrize("name", list(WORKLOADS))
def test_baselines_feasible(name):
    slo = workload_slo(name)
    env = SimulatedPlatform().environment()
    best = maff_search(WORKLOADS[name](), slo, env)
    assert best is not None and best.e2e_runtime <= slo
    env = SimulatedPlatform().environment()
    best = bo_search(WORKLOADS[name](), slo, env, n_rounds=25)
    assert best is not None and best.e2e_runtime <= slo


@pytest.mark.parametrize("name", list(WORKLOADS))
def test_aarc_beats_baselines_on_cost(name):
    """Table II directional claim: AARC's optimal config is cheaper."""
    out = run_all(name)
    assert out["aarc"][0] < out["maff"][0], \
        f"AARC {out['aarc'][0]:.2f} vs MAFF {out['maff'][0]:.2f}"
    assert out["aarc"][0] < out["bo"][0], \
        f"AARC {out['aarc'][0]:.2f} vs BO {out['bo'][0]:.2f}"


def test_aarc_search_time_beats_bo():
    """Fig. 5 directional claim: total search wall time is far lower
    (AARC re-invokes single functions; BO re-runs whole workflows)."""
    out = run_all("video_analysis", bo_rounds=40)
    assert out["aarc"][1] < 0.5 * out["bo"][1]


def test_maff_stuck_in_local_optimum_on_cpu_heavy():
    """ML Pipeline (§IV-B): coupled descent cannot express
    (high cpu, low mem) so it pays for memory it does not need."""
    out = run_all("ml_pipeline")
    aarc_cost, maff_cost = out["aarc"][0], out["maff"][0]
    assert aarc_cost < 0.7 * maff_cost
