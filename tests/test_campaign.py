"""Portfolio-campaign pipeline: generator → searchers → fleet replay."""
import math

import pytest

from repro.core.campaign import (Campaign, CampaignSpec, PortfolioSpec,
                                 ReplaySpec, run_campaign)
from repro.core.engine import ClusterModel


SMALL = CampaignSpec(
    portfolio=PortfolioSpec(n_workflows=4, size=6, slo_slacks=(1.5, 2.5)),
    replay=ReplaySpec(n_instances=8, rate=0.5),
    searchers=("aarc", "maff"),
    searcher_kwargs={"aarc": {"batch_size": 4}},
    seed=11)


@pytest.fixture(scope="module")
def report():
    return run_campaign(SMALL)


def test_campaign_covers_the_full_grid(report):
    # 4 workflows x 2 SLO slacks x 2 searchers
    assert len(report.results) == 16
    by = report.by_searcher()
    assert set(by) == {"aarc", "maff"}
    assert all(len(rows) == 8 for rows in by.values())
    kinds = {r.task.kind for r in report.results}
    assert kinds == {"chain", "fan", "diamond", "layered"}


def test_tasks_are_seed_reproducible():
    t1 = Campaign(SMALL).tasks()
    t2 = Campaign(SMALL).tasks()
    assert [(t.kind, t.wf_seed, t.slo) for t in t1] == \
        [(t.kind, t.wf_seed, t.slo) for t in t2]
    t3 = Campaign(CampaignSpec(portfolio=SMALL.portfolio,
                               replay=SMALL.replay,
                               searchers=SMALL.searchers, seed=12)).tasks()
    assert [t.wf_seed for t in t1] != [t.wf_seed for t in t3]


def test_campaign_is_deterministic(report):
    again = run_campaign(SMALL)
    assert [r.search.cost for r in report.results] == \
        [r.search.cost for r in again.results]
    assert [r.replay.slo_attainment for r in report.results] == \
        [r.replay.slo_attainment for r in again.results]


def test_replay_metrics_are_sane(report):
    for r in report.results:
        assert r.replay is not None
        assert 0.0 <= r.replay.slo_attainment <= 1.0
        assert r.replay.total_cost > 0.0
        assert r.replay.p99_s >= r.replay.p50_s
        if r.search.feasible:
            # infinite cluster, no cold start: every instance realizes
            # the searched latency, so attainment is total
            assert r.replay.slo_attainment == 1.0


def test_summary_reports_search_time_deltas(report):
    summary = report.summary()
    for agg in summary.values():
        assert agg["n_tasks"] == 8
        assert 0.0 <= agg["feasible_rate"] <= 1.0
        assert math.isfinite(agg["total_search_time_s"])
        assert "search_time_reduction_vs_worst" in agg
    # AARC's single-function trials must beat MAFF's full-workflow
    # samples on modeled search time (the paper's headline claim,
    # generalized to the generated portfolio)
    assert summary["aarc"]["total_search_time_s"] < \
        summary["maff"]["total_search_time_s"]


def test_rows_flatten_for_emission(report):
    rows = report.to_rows()
    assert len(rows) == len(report.results)
    for row in rows:
        assert {"searcher", "kind", "slo_s", "feasible", "n_samples",
                "replay_slo_attainment"} <= set(row)


def test_constrained_cluster_replay_queues():
    spec = CampaignSpec(
        portfolio=PortfolioSpec(n_workflows=2, size=6, kinds=("fan",),
                                slo_slacks=(2.0,)),
        replay=ReplaySpec(n_instances=16, rate=2.0,
                          cluster=ClusterModel(total_cpu=20.0,
                                               total_mem_mb=20480.0)),
        searchers=("aarc",), seed=3)
    report = run_campaign(spec)
    assert any(r.replay.total_queue_delay_s > 0.0 for r in report.results)


def test_campaign_without_replay():
    report = run_campaign(CampaignSpec(
        portfolio=PortfolioSpec(n_workflows=2, size=5, slo_slacks=(2.0,)),
        searchers=("maff",), seed=5), with_replay=False)
    assert all(r.replay is None for r in report.results)
    agg = report.summary()["maff"]
    assert math.isnan(agg["mean_slo_attainment"])
