"""``FleetCarry.pruned`` boundary semantics, pinned.

The contract (documented on :meth:`FleetCarry.pruned`): at an epoch
boundary ``t``, a warm container with ``expire_t == t`` is KEPT (still
claimable at exactly ``t``, mirroring the engine's ``expire >= t``
claim condition), a capacity reservation with ``finish_t == t`` is
DROPPED (released at ``t``; the engine equally ignores carried
reservations with ``finish <= first arrival``) — and the two rules
together never double-count a container as both busy and warm, nor
leak phantom capacity across the boundary."""
import pytest

from repro.core.backend import CallableBackend
from repro.core.dag import Workflow
from repro.core.engine import ColdStartModel, FleetCarry, FleetEngine

CONST = CallableBackend(lambda node: 1.0)


def _svc(tenant):
    wf = Workflow("svc", tenant=tenant)
    wf.add_function("f")
    return wf


def test_carry_pruned_keeps_warm_expiring_exactly_at_boundary():
    carry = FleetCarry(clock=5.0,
                       warm={("A", "f"): [[0.0, 10.0], [0.0, 4.0]]})
    out = carry.pruned(10.0)
    assert out.clock == 10.0
    assert out.warm == {("A", "f"): [[0.0, 10.0]]}   # expire == t kept


def test_carry_pruned_drops_reservation_finishing_at_boundary():
    carry = FleetCarry(busy=[(10.0, 2.0, 1024.0), (10.5, 1.0, 512.0)])
    out = carry.pruned(10.0)
    assert out.busy == [(10.5, 1.0, 512.0)]          # finish == t dropped


def test_carry_pruned_drops_empty_pools_and_keeps_tenant_keys():
    carry = FleetCarry(warm={("A", "f"): [[0.0, 50.0]],
                             ("B", "f"): [[0.0, 1.0]]})
    out = carry.pruned(10.0)
    assert set(out.warm) == {("A", "f")}   # B's pool fully expired
    # pruning copies — mutating the pruned pool must not leak back
    out.warm[("A", "f")][0][1] = 0.0
    assert carry.warm[("A", "f")] == [[0.0, 50.0]]


def test_carry_boundary_container_claimable_not_double_counted():
    """An invocation finishing exactly at the boundary ``t``: its
    capacity reservation is released (dropped from ``busy``) while the
    warm container it deposited survives — and a next-epoch instance
    arriving at exactly ``t`` claims it without a cold start."""
    engine = FleetEngine(
        CONST, cold_start=ColdStartModel(delay_s=5.0, keep_alive_s=600.0))
    first = engine.run([_svc("A")], [0.0], collect_carry=True)
    finish = float(first.finishes[0])                # 0 + 5 cold + 1 run
    assert finish == 6.0

    carry = first.carry.pruned(finish)
    # released: no reservation survives its own finish time
    assert all(f > finish for f, _, _ in carry.busy)
    assert carry.busy == []
    # ...but the container it deposited is in the warm pool, live
    assert ("A", "f") in carry.warm
    deposit_t, expire_t = carry.warm[("A", "f")][0]
    assert deposit_t == finish and expire_t == finish + 600.0

    second = engine.run([_svc("A")], [finish], carry=carry)
    assert float(second.cold_delays[0]) == 0.0       # claimed warm
    # the claim is tenant-scoped: another tenant at the same boundary
    # still pays its own cold start from the same carry
    other = engine.run([_svc("B")], [finish], carry=carry)
    assert float(other.cold_delays[0]) == 5.0


def test_carry_warm_expired_before_boundary_is_not_claimable():
    engine = FleetEngine(
        CONST, cold_start=ColdStartModel(delay_s=5.0, keep_alive_s=2.0))
    first = engine.run([_svc("A")], [0.0], collect_carry=True)
    finish = float(first.finishes[0])
    # keep-alive 2s: container expires at finish + 2
    carry = first.carry.pruned(finish + 2.0)
    assert ("A", "f") in carry.warm                  # expire == t: kept
    late = engine.run([_svc("A")], [finish + 2.5], carry=carry)
    assert float(late.cold_delays[0]) == 5.0         # expired by 2.5
    exact = engine.run([_svc("A")], [finish + 2.0], carry=carry)
    assert float(exact.cold_delays[0]) == 0.0        # claimable AT t
