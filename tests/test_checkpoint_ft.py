"""Checkpointing (atomic, elastic) + fault-tolerant training loop."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.distributed.fault_tolerance import (InjectedFault, LoopReport,
                                               ResilientLoop, StepWatchdog)
from repro.models.model import Model
from repro.training.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
from repro.training.data import SyntheticDataset
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step


def toy_state():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones(3)},
            "m": {"w": jnp.zeros((2, 3)), "b": jnp.zeros(3)},
            "v": {"w": jnp.zeros((2, 3)), "b": jnp.zeros(3)},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    state = toy_state()
    save_checkpoint(d, 7, state, extra={"note": "hi"})
    assert latest_step(d) == 7
    restored, step, extra = restore_checkpoint(d, like=state)
    assert step == 7 and extra == {"note": "hi"}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incomplete_checkpoint_ignored(tmp_path):
    """A crash mid-write (no manifest) must be invisible to restore."""
    d = str(tmp_path / "ckpt")
    state = toy_state()
    save_checkpoint(d, 5, state)
    broken = os.path.join(d, "step_00000009")
    os.makedirs(broken)                   # dir exists, no manifest
    with open(os.path.join(broken, "shard_0.npz"), "wb") as f:
        f.write(b"garbage")
    assert latest_step(d) == 5
    restored, step, _ = restore_checkpoint(d, like=state)
    assert step == 5


def test_keep_last_k(tmp_path):
    d = str(tmp_path / "ckpt")
    state = toy_state()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, state, keep=2)
    steps = sorted(int(n[5:]) for n in os.listdir(d))
    assert steps == [4, 5]


def test_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, toy_state())
    bad = toy_state()
    bad["params"]["w"] = jnp.zeros((3, 3))
    with pytest.raises(ValueError):
        restore_checkpoint(d, like=bad)


def make_loop_pieces(tmp_path, lr=1e-3):
    cfg = reduced_config("olmo-1b", n_layers=2)
    model = Model(cfg)
    state = adamw_init(model.init(jax.random.key(0)))
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=16, global_batch=4)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=lr)))
    return state, ds, step


def test_resilient_loop_recovers_from_faults(tmp_path):
    state, ds, step = make_loop_pieces(tmp_path)
    failed = set()

    def fault_hook(step_idx):
        # fail once each at steps 7 and 13, after checkpoints exist
        if step_idx in (7, 13) and step_idx not in failed:
            failed.add(step_idx)
            raise InjectedFault(f"node died at step {step_idx}")

    loop = ResilientLoop(step, state, ckpt_dir=str(tmp_path / "ck"),
                         ckpt_every=5, fault_hook=fault_hook)
    report = loop.run(ds, until_step=20)
    assert report.final_step == 20
    assert report.failures == 2
    assert report.restores == 2


def test_recovery_is_exactly_deterministic(tmp_path):
    """Loss trajectory after crash+restore == uninterrupted trajectory
    (step-keyed data + exact state restore)."""
    # uninterrupted reference
    state, ds, step = make_loop_pieces(tmp_path)
    ref_losses = {}
    s = state
    for i in range(12):
        s, m = step(s, ds.batch_at(i))
        ref_losses[i] = float(m["loss"])

    # faulty run
    state, ds, step = make_loop_pieces(tmp_path)
    seen = {}

    def record_step(st, batch):
        st2, m = step(st, batch)
        seen[int(st["step"])] = float(m["loss"])
        return st2, m

    failed = set()

    def fault_hook(i):
        if i == 8 and i not in failed:
            failed.add(i)
            raise InjectedFault("boom")

    loop = ResilientLoop(record_step, state, ckpt_dir=str(tmp_path / "ck2"),
                         ckpt_every=4, fault_hook=fault_hook)
    report = loop.run(ds, until_step=12)
    assert report.restores == 1
    for i, loss in ref_losses.items():
        assert seen[i] == pytest.approx(loss, rel=1e-6), f"step {i}"


def test_watchdog_flags_stragglers():
    wd = StepWatchdog(threshold=3.0, window=16)
    for i in range(10):
        wd.observe(i, 0.1)
    assert wd.observe(10, 0.5)           # 5x median -> straggler
    assert not wd.observe(11, 0.12)
    assert wd.straggler_steps == [10]
