"""Graph queries of Algorithm 1 (critical path / detours / windows) —
unit cases + hypothesis property tests on random DAGs."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.dag import Node, Workflow
from repro.core.critical_path import (find_critical_path,
                                      find_detour_subpath, runtime_sum)


def diamond(wa=1.0, wb=5.0, wc=2.0, wd=1.0):
    wf = Workflow("diamond")
    for name, w in (("a", wa), ("b", wb), ("c", wc), ("d", wd)):
        wf.add_function(name)
        wf.nodes[name].runtime = w
    wf.add_edge("a", "b")
    wf.add_edge("a", "c")
    wf.add_edge("b", "d")
    wf.add_edge("c", "d")
    return wf


def test_critical_path_picks_heavier_branch():
    wf = diamond()
    assert find_critical_path(wf) == ["a", "b", "d"]
    wf2 = diamond(wb=1.0, wc=9.0)
    assert find_critical_path(wf2) == ["a", "c", "d"]


def test_e2e_latency_is_longest_path():
    wf = diamond()
    assert wf.end_to_end_latency() == pytest.approx(1 + 5 + 1)


def test_detour_subpath_of_diamond():
    wf = diamond()
    cp = find_critical_path(wf)
    subs = find_detour_subpath(wf, cp)
    assert len(subs) == 1
    sp = subs[0]
    assert sp.start == "a" and sp.end == "d" and sp.interior == ["c"]
    # sub-SLO window = time the critical path spends between the anchors
    assert runtime_sum(wf, cp, sp.start, sp.end) == pytest.approx(5.0)


def test_detour_from_source_to_sink():
    wf = Workflow()
    for n, w in (("a", 3.0), ("b", 1.0), ("x", 0.5)):
        wf.add_function(n)
        wf.nodes[n].runtime = w
    wf.add_edge("a", "b")
    wf.add_edge("x", "b")           # x is an off-CP source
    cp = find_critical_path(wf)
    assert cp == ["a", "b"]
    subs = find_detour_subpath(wf, cp)
    assert any(s.start is None and s.interior == ["x"] for s in subs)


def test_cycle_rejected():
    wf = Workflow()
    wf.add_function("a")
    wf.add_function("b")
    wf.add_edge("a", "b")
    with pytest.raises(ValueError):
        wf.add_edge("b", "a")


# -- property tests ----------------------------------------------------------

@st.composite
def random_dag(draw):
    n = draw(st.integers(3, 12))
    wf = Workflow("rand")
    names = [f"n{i}" for i in range(n)]
    for name in names:
        wf.add_function(name)
        wf.nodes[name].runtime = draw(
            st.floats(0.1, 10.0, allow_nan=False, allow_infinity=False))
    # edges only i -> j with i < j: guaranteed acyclic
    for i in range(n):
        for j in range(i + 1, n):
            if draw(st.booleans()) and draw(st.integers(0, 2)) == 0:
                wf.add_edge(names[i], names[j])
    return wf


@given(random_dag())
@settings(max_examples=60, deadline=None)
def test_critical_path_properties(wf):
    cp = find_critical_path(wf)
    assert cp, "non-empty DAG must have a critical path"
    # path is connected
    for a, b in zip(cp, cp[1:]):
        assert b in wf.successors(a)
    # its weight equals the end-to-end latency
    assert wf.path_latency(cp) == pytest.approx(wf.end_to_end_latency())
    # no other path is longer: compare against every simple source path
    # via DP (end_to_end_latency is already the DP longest path)
    assert wf.path_latency(cp) >= max(
        wf.nodes[n].runtime for n in wf.nodes)


@given(random_dag())
@settings(max_examples=60, deadline=None)
def test_detours_cover_off_cp_nodes(wf):
    cp = find_critical_path(wf)
    subs = find_detour_subpath(wf, cp)
    covered = set()
    for sp in subs:
        covered.update(sp.interior)
        # interior nodes are strictly off the critical path
        assert not (set(sp.interior) & set(cp))
        # anchors, when present, are on the critical path
        assert sp.start is None or sp.start in cp
        assert sp.end is None or sp.end in cp
    # every reachable off-CP node with a connection to the DAG appears
    # in at least one detour (detours + flags give full coverage)
    off = set(wf.nodes) - set(cp)
    orphan = {n for n in off
              if not wf.predecessors(n) and not wf.successors(n)}
    assert covered >= off - orphan


@given(random_dag())
@settings(max_examples=40, deadline=None)
def test_runtime_sum_windows_are_consistent(wf):
    cp = find_critical_path(wf)
    total = runtime_sum(wf, cp, None, None)
    assert total == pytest.approx(wf.path_latency(cp))
    if len(cp) >= 2:
        # window between consecutive anchors is empty
        assert runtime_sum(wf, cp, cp[0], cp[1]) == pytest.approx(0.0)
