"""Distributed pieces that need >1 device: run in a subprocess with
forced host devices (the main test process must keep 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_forked(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    return out.stdout


def test_quantize_roundtrip_bounds():
    from repro.distributed.collectives import dequantize_int8, quantize_int8
    import jax
    x = jax.random.normal(jax.random.key(0), (256,)) * 3.0
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_int8_psum_with_error_feedback():
    """2-pod quantized all-reduce: mean is close; error feedback stores
    exactly what quantization dropped."""
    run_forked("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.collectives import cross_pod_grad_sync

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        g = jax.random.normal(jax.random.key(0), (2, 64))  # per-pod rows

        def f(gs, es):
            s, e = cross_pod_grad_sync({"w": gs}, {"w": es}, "pod")
            return s["w"], e["w"]

        fn = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                       out_specs=(P("pod"), P("pod")), check_rep=False)
        synced, err = fn(g, jnp.zeros_like(g))
        true_mean = g.mean(axis=0)
        got = np.asarray(synced)[0]
        scale = float(jnp.abs(g).max()) / 127.0
        assert np.abs(got - np.asarray(true_mean)).max() <= scale, \\
            (np.abs(got - np.asarray(true_mean)).max(), scale)
        # error feedback equals what each pod's quantization dropped
        assert np.abs(np.asarray(err)).max() <= scale
        print("OK")
    """)


def test_sharded_train_step_runs_and_matches_single_device():
    """8-device (2,4)-mesh FSDP train step == single-device step."""
    run_forked("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import reduced_config
        from repro.launch.steps import build_train_step
        from repro.training.data import SyntheticDataset
        from repro.training.optimizer import AdamWConfig, adamw_init
        from repro.training.train_step import make_train_step
        from repro.models.model import Model

        cfg = reduced_config("olmo-1b", n_layers=2, d_model=64, d_ff=128,
                             n_heads=2, kv_heads=2, head_dim=32)
        model = Model(cfg)
        ds = SyntheticDataset(vocab=cfg.vocab, seq_len=16, global_batch=8)
        batch = ds.batch_at(0)

        # single-device reference
        state0 = adamw_init(model.init(jax.random.key(0)))
        step = make_train_step(model, AdamWConfig(lr=1e-3))
        ref_state, ref_m = jax.jit(step)(state0, batch)

        # sharded execution on a (data=2, model=4) mesh
        from repro.configs.shapes import Shape
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shape = Shape("t", 16, 8, "train")
        bundle = build_train_step(cfg, shape, mesh, donate=False)
        compiled = bundle.lowered.compile()
        sh_state, sh_m = compiled(state0, batch)
        assert np.isfinite(float(sh_m["loss"]))
        np.testing.assert_allclose(float(sh_m["loss"]),
                                   float(ref_m["loss"]), rtol=1e-4)
        fr = np.concatenate([np.asarray(x, np.float32).ravel()
                             for x in jax.tree.leaves(ref_state["params"])])
        fs = np.concatenate([np.asarray(x, np.float32).ravel()
                             for x in jax.tree.leaves(sh_state["params"])])
        np.testing.assert_allclose(fs, fr, atol=1e-4, rtol=1e-3)
        print("OK")
    """)


def test_elastic_reshard_across_meshes():
    """State sharded on a (4,2) mesh restores onto (2,2) and (8,1)."""
    run_forked("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.registry import reduced_config
        from repro.distributed.fault_tolerance import elastic_reshard
        from repro.distributed.sharding import FSDP_RULES, tree_shardings
        from repro.models.model import Model
        from repro.training.optimizer import adamw_init, train_state_axes

        cfg = reduced_config("olmo-1b", n_layers=2)
        model = Model(cfg)
        params, axes = model.build(jax.random.key(0))
        state = adamw_init(params)
        st_axes = train_state_axes(axes)

        m1 = jax.make_mesh((4, 2), ("data", "model"))
        sh1 = tree_shardings(m1, FSDP_RULES, st_axes, state)
        state1 = jax.tree.map(jax.device_put, state, sh1)

        m2 = jax.make_mesh((2, 2), ("data", "model"))
        state2 = elastic_reshard(state1, st_axes, m2, FSDP_RULES)
        a = np.asarray(jax.device_get(state1["params"]["embed"]["tok"]))
        b = np.asarray(jax.device_get(state2["params"]["embed"]["tok"]))
        np.testing.assert_array_equal(a, b)
        print("OK")
    """)


def test_multipod_mesh_constructs():
    """make_production_mesh(multi_pod=True) builds (2,16,16) = 512."""
    run_forked("""
        from repro.launch.mesh import make_production_mesh
        m = make_production_mesh(multi_pod=True)
        assert dict(m.shape) == {"pod": 2, "data": 16, "model": 16}
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}
        print("OK")
    """, devices=512)
