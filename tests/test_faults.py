"""Fault-injection plane + recovery policy: the paired fault-stream
contract, deterministic retry/timeout/hedge semantics, placement-aware
outage blast radius, and the ``faults=None`` identity pin.

The load-bearing invariants:

  * **paired streams** — one :meth:`FaultModel.fault_stream` rng
    advance per replay plane, draws keyed by ``(attempt, instance,
    function)``: the same configuration in two candidate slots of one
    batch replays byte-identical outcomes (challenger validation is a
    paired experiment, exactly like ``replay_noise``);
  * **plane parity** — the serial event loop and the constrained
    table plane resolve faults through the same float operations, so
    ``run`` vs ``run_many`` is bit-identical under faults;
  * **faults=None identity** — an engine constructed with explicit
    ``faults=None, resilience=None`` is the plain engine: same plane
    routing, same reports, no behavioural residue;
  * **recovery semantics** — retries charge every attempt and back off
    exponentially, timeouts kill and bill stragglers, hedges race a
    burst duplicate with cancel-on-completion billing.
"""
import math

import numpy as np
import pytest

from repro.core.backend import CallableBackend
from repro.core.dag import Node, Workflow
from repro.core.engine import (ClusterModel, ColdStartModel, FleetEngine,
                               PoissonArrivals, run_fleet)
from repro.core.engine import _stranded_error
from repro.core.faults import (FaultModel, FaultStream, MAX_ATTEMPTS,
                               NO_RECOVERY, OutageWindow, ResilienceModel,
                               ResiliencePolicy, ResilienceSpec,
                               classify_failures, degrade_policies,
                               grant_policies, ladder_level, policy_ladder)
from repro.core.resources import ResourceConfig
from repro.core.search import make_searcher
from repro.serverless.generator import chain_workflow, suggest_slo
from repro.serverless.platform import SimulatedPlatform

CONSTRAINED_KW = dict(cluster=ClusterModel(total_cpu=48.0,
                                           total_mem_mb=48.0 * 1024.0),
                      cold_start=ColdStartModel(delay_s=0.25,
                                                keep_alive_s=60.0))

FAULTS = FaultModel(default_transient=0.25, straggler_prob=0.15,
                    straggler_factor=5.0, seed=3)

RETRIES = ResilienceModel(default=ResiliencePolicy(max_retries=2,
                                                   backoff_s=0.05))


def make_engine(**kw):
    env = SimulatedPlatform().environment()
    return FleetEngine(env.backend, pricing=env.pricing, **kw)


def candidate_sets(template, n_cand, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_cand):
        out.append({n.name: ResourceConfig(cpu=float(rng.uniform(1.0, 8.0)),
                                           mem=float(rng.uniform(1024.0,
                                                                 8192.0)))
                    for n in template})
    return out


def arrival_sets(n_seeds, n=8, rate=0.25):
    return [PoissonArrivals(rate, n, seed=s).times() for s in range(n_seeds)]


def scalar_cell(engine, template, configs, times):
    wfs = []
    for _ in range(len(times)):
        wf = template.copy()
        wf.apply_configs(configs)
        wfs.append(wf)
    return engine.run(wfs, times)


def assert_reports_identical(got, want):
    assert np.array_equal(got.arrivals, want.arrivals)
    assert np.array_equal(got.finishes, want.finishes)
    assert np.array_equal(got.latencies, want.latencies)
    assert np.array_equal(got.queue_delays, want.queue_delays)
    assert np.array_equal(got.cold_delays, want.cold_delays)
    assert np.array_equal(got.costs, want.costs)
    assert np.array_equal(got.failed_mask, want.failed_mask)
    assert got.makespan == want.makespan
    assert got.total_cost == want.total_cost
    assert got.total_retries == want.total_retries
    assert got.total_timeouts == want.total_timeouts
    assert got.total_hedges == want.total_hedges
    assert got.total_failures == want.total_failures


def single_node_wf(rt_oracle, cpu=2.0, tenant=None):
    wf = Workflow("unit", tenant=tenant)
    wf.add_node(Node(name="f", config=ResourceConfig(cpu=cpu, mem=2048.0)))
    return wf, CallableBackend(rt_oracle)


# -- the paired fault-stream contract ----------------------------------

def test_fault_stream_is_one_draw_keyed_by_coordinates():
    """Same seed + same plane shape => byte-identical tensors; the
    draw is a function of the coordinate grid, not of call order."""
    a = FaultModel(seed=7).fault_stream(12, 4)
    b = FaultModel(seed=7).fault_stream(12, 4)
    assert isinstance(a, FaultStream)
    assert a.primary.shape == (3, MAX_ATTEMPTS, 12, 4)
    assert np.array_equal(a.primary, b.primary)
    assert np.array_equal(a.hedge, b.hedge)
    assert not np.array_equal(a.primary,
                              FaultModel(seed=8).fault_stream(12, 4).primary)


def test_run_many_consumes_one_fault_stream_draw_per_plane(monkeypatch):
    """The plane advances the fault rng exactly once — never per
    cell/candidate — which is what makes batched replays paired."""
    template = chain_workflow(4, seed=11)
    draws = {"n": 0}
    real = FaultModel.fault_stream

    def counting(self, n_instances, n_functions):
        draws["n"] += 1
        return real(self, n_instances, n_functions)

    monkeypatch.setattr(FaultModel, "fault_stream", counting)
    engine = make_engine(faults=FAULTS, resilience=RETRIES)
    reports = engine.run_many(template, candidate_sets(template, 3, seed=9),
                              arrival_sets(2))
    assert draws["n"] == 1
    assert len(reports) == 6


def test_same_configs_in_two_candidate_slots_draw_the_same_faults():
    """Paired experiment across the batch: duplicate candidates replay
    bit-identically, so report deltas are policy, never luck."""
    template = chain_workflow(4, seed=11)
    cfg_a, cfg_b = candidate_sets(template, 2, seed=5)
    engine = make_engine(faults=FAULTS, resilience=RETRIES)
    reports = engine.run_many(template, [cfg_a, cfg_b, cfg_a],
                              arrival_sets(1, n=12))
    assert_reports_identical(reports[2], reports[0])
    assert not np.array_equal(reports[1].latencies, reports[0].latencies)


@pytest.mark.parametrize("engine_kw", [{}, CONSTRAINED_KW],
                         ids=["infinite", "constrained"])
def test_serial_run_matches_run_many_under_faults(engine_kw):
    """The scalar event loop and the vectorized table plane must agree
    bit-for-bit on fault outcomes AND recovery tallies."""
    template = chain_workflow(5, seed=11)
    configs = candidate_sets(template, 1, seed=2)[0]
    times = arrival_sets(1, n=10)[0]
    kw = dict(engine_kw, faults=FAULTS, resilience=RETRIES)
    batched = make_engine(**kw).run_many(template, [configs], [times])[0]
    serial = scalar_cell(make_engine(**kw), template, configs, times)
    assert_reports_identical(batched, serial)
    assert batched.total_failures > 0          # the schedule has teeth


def test_faults_none_engine_is_bit_identical_to_plain():
    """Explicit ``faults=None, resilience=None`` is the pinned no-op
    path on both the fast and the constrained plane."""
    template = chain_workflow(5, seed=11)
    cands = candidate_sets(template, 2, seed=4)
    seeds = arrival_sets(2)
    for kw in ({}, CONSTRAINED_KW):
        plain = make_engine(**kw).run_many(template, cands, seeds)
        explicit = make_engine(faults=None, resilience=None,
                               **kw).run_many(template, cands, seeds)
        for got, want in zip(explicit, plain):
            assert_reports_identical(got, want)


def test_fault_injection_routes_off_the_fast_plane():
    template = chain_workflow(4, seed=11)
    plain = make_engine().batch_eligibility(template, [])
    assert plain["plane"] == "fast"
    faulty = make_engine(faults=FAULTS).batch_eligibility(template, [])
    assert faulty["plane"] == "constrained" and faulty["vectorized"]
    assert any("fault" in r for r in faulty["reasons"])


# -- deterministic recovery semantics ----------------------------------

def _split_rate(lo, hi):
    """A probability strictly between two uniforms (draw ``lo`` fires,
    draw ``hi`` does not)."""
    assert lo < hi, "pick a seed where the draws are ordered"
    return (lo + hi) / 2.0


def _seed_where(channel, lane="primary"):
    """A seed whose attempt-0 draw is below its attempt-1 draw on one
    channel (so a split rate fails attempt 0 and passes attempt 1)."""
    for seed in range(64):
        s = FaultModel(seed=seed).fault_stream(1, 1)
        t = s.primary if lane == "primary" else s.hedge
        if t[channel, 0, 0, 0] < t[channel, 1, 0, 0]:
            return seed, s
    raise AssertionError("no ordered seed in range")


def test_retry_charges_every_attempt_and_backs_off():
    """attempt 0 burns its full runtime and fails; the retry launches
    ``backoff_s`` later and succeeds: latency = 2*rt + backoff, cost =
    2x the clean run."""
    rt, backoff = 3.0, 0.125
    seed, stream = _seed_where(channel=0)
    rate = _split_rate(stream.primary[0, 0, 0, 0],
                       stream.primary[0, 1, 0, 0])
    wf, backend = single_node_wf(lambda node: rt)
    faults = FaultModel(default_transient=rate, seed=seed)
    policy = ResilienceModel(default=ResiliencePolicy(max_retries=2,
                                                      backoff_s=backoff))
    clean = FleetEngine(CallableBackend(lambda n: rt)).run([wf.copy()], [0.0])
    rep = FleetEngine(backend, faults=faults,
                      resilience=policy).run([wf], [0.0])
    assert rep.latencies[0] == 2 * rt + backoff
    assert rep.total_retries == 1 and rep.total_failures == 1
    assert not rep.failed_mask[0]
    assert rep.costs[0] == pytest.approx(2 * clean.costs[0])


def test_unrecovered_transient_fault_kills_the_instance():
    """Without a retry budget the failed attempt is a dead instance —
    billed for the burned runtime, excluded from goodput."""
    rt = 3.0
    seed, stream = _seed_where(channel=0)
    rate = _split_rate(stream.primary[0, 0, 0, 0],
                       stream.primary[0, 1, 0, 0])
    wf, backend = single_node_wf(lambda node: rt)
    rep = FleetEngine(backend, faults=FaultModel(default_transient=rate,
                                                 seed=seed)).run([wf], [0.0])
    assert rep.failed_mask[0]
    assert rep.latencies[0] == rt              # the burn IS the wall time
    assert rep.total_failures == 1 and rep.total_retries == 0
    assert rep.costs[0] > 0.0                  # the burn is billed
    assert rep.goodput(slo=1e9) == 0.0         # dead => never goodput
    assert rep.completion(1e9) == 1.0          # on time but wrong


def test_timeout_kills_the_straggler_and_bills_the_executed_slice():
    """attempt 0 straggles to factor*rt, is guillotined at timeout_s,
    and the retry (no straggle) lands: latency = timeout + backoff +
    rt, exactly one timeout on the ledger."""
    rt, factor, backoff = 2.0, 10.0, 0.25
    seed, stream = _seed_where(channel=1)
    prob = _split_rate(stream.primary[1, 0, 0, 0],
                       stream.primary[1, 1, 0, 0])
    timeout = 3.0 * rt                         # < factor * rt
    wf, backend = single_node_wf(lambda node: rt)
    faults = FaultModel(straggler_prob=prob, straggler_factor=factor,
                        seed=seed)
    policy = ResilienceModel(default=ResiliencePolicy(
        max_retries=1, timeout_s=timeout, backoff_s=backoff))
    rep = FleetEngine(backend, faults=faults,
                      resilience=policy).run([wf], [0.0])
    assert rep.latencies[0] == timeout + backoff + rt
    assert rep.total_timeouts == 1 and rep.total_retries == 1
    assert not rep.failed_mask[0]


def test_hedge_races_the_straggler_and_earliest_success_wins():
    """The primary straggles; the hedge (independent draw lane) does
    not: the duplicate fires at hedge_delay_s on burst capacity and
    resolves the attempt at hedge_delay + rt."""
    rt, factor, delay = 2.0, 8.0, 1.0
    for seed in range(128):
        s = FaultModel(seed=seed).fault_stream(1, 1)
        if s.primary[1, 0, 0, 0] < s.hedge[1, 0, 0, 0]:
            prob = _split_rate(s.primary[1, 0, 0, 0], s.hedge[1, 0, 0, 0])
            break
    else:
        raise AssertionError("no ordered seed in range")
    wf, backend = single_node_wf(lambda node: rt)
    faults = FaultModel(straggler_prob=prob, straggler_factor=factor,
                        seed=seed)
    policy = ResilienceModel(default=ResiliencePolicy(hedge_delay_s=delay))
    rep = FleetEngine(backend, faults=faults,
                      resilience=policy).run([wf], [0.0])
    assert rep.latencies[0] == delay + rt      # hedge leg wins
    assert rep.total_hedges == 1
    assert not rep.failed_mask[0]
    no_hedge = FleetEngine(backend, faults=faults).run([wf.copy()], [0.0])
    assert no_hedge.latencies[0] == factor * rt
    clean = FleetEngine(backend).run([wf.copy()], [0.0])
    # both legs billed (cancel-on-completion): dearer than a clean run,
    # though cheaper here than letting the straggler burn to the end
    assert rep.costs[0] > clean.costs[0]


def test_hedge_past_the_finish_never_fires():
    rt = 2.0
    wf, backend = single_node_wf(lambda node: rt)
    policy = ResilienceModel(default=ResiliencePolicy(hedge_delay_s=5 * rt))
    rep = FleetEngine(backend, faults=FaultModel(seed=0),
                      resilience=policy).run([wf], [0.0])
    assert rep.latencies[0] == rt and rep.total_hedges == 0


# -- correlated outages + placement ------------------------------------

def test_outage_blast_radius_follows_the_placement_map():
    """outage_fail=1.0 on node 0 kills exactly the tenant placed there
    (admission-time windows); the anti-affinity-spread tenant on node 1
    is untouched."""
    rt = 1.0
    window = OutageWindow(node=0, start_s=0.0, end_s=100.0)
    faults = FaultModel(outages=(window,), node_of={"A": 0, "B": 1},
                        outage_fail=1.0, seed=0)
    wfs, times = [], []
    for tenant in ("A", "B"):
        for k in range(4):
            wf, backend = single_node_wf(lambda node: rt, tenant=tenant)
            wfs.append(wf)
            times.append(float(k))
    rep = FleetEngine(backend, faults=faults).run(wfs, times)
    assert rep.tenant_slice("A").failed_mask.all()
    assert not rep.tenant_slice("B").failed_mask.any()


def test_fault_outage_window_is_admission_time():
    """An attempt admitted after the window ends succeeds even though
    the outage overlapped the fleet's lifetime."""
    faults = FaultModel(outages=(OutageWindow(node=0, start_s=0.0,
                                              end_s=5.0),),
                        node_of={"A": 0}, seed=0)
    assert faults.outage_active("A", "f", 4.999)
    assert not faults.outage_active("A", "f", 5.0)
    assert faults.effective_transient("A", "f", 1.0) == 1.0
    assert faults.effective_transient("A", "f", 6.0) == 0.0
    assert faults.effective_transient("B", "f", 1.0) == 0.0  # unplaced


def test_fault_rate_and_policy_key_resolution_precedence():
    """(identity, name) beats the bare name beats the default — the
    ReplicaModel convention, shared by faults and policies."""
    fm = FaultModel(transient={("t1", "f"): 0.5, "f": 0.25},
                    default_transient=0.1)
    assert fm.rate("t1", "f") == 0.5
    assert fm.rate("t2", "f") == 0.25
    assert fm.rate("t2", "g") == 0.1
    pol = ResiliencePolicy(max_retries=2)
    rm = ResilienceModel(policies={("t1", "f"): pol,
                                   "f": ResiliencePolicy(max_retries=1)})
    assert rm.policy("t1", "f") is pol
    assert rm.policy("t2", "f").max_retries == 1
    assert rm.policy("t2", "g") is NO_RECOVERY


# -- report ledgers ----------------------------------------------------

def test_saturation_reports_per_function_failure_rows():
    template = chain_workflow(4, seed=11)
    configs = candidate_sets(template, 1, seed=2)[0]
    wf = template.copy()
    wf.apply_configs(configs)
    env = SimulatedPlatform().environment()
    rep = run_fleet(env, wf, PoissonArrivals(0.5, 24, seed=1),
                    faults=FAULTS, resilience=RETRIES)
    sat = rep.saturation()
    assert sat, "saturation must have per-function rows"
    for row in sat.values():
        assert {"failed", "failure_share"} <= set(row)
    total, share = classify_failures(sat)
    assert total == rep.total_failures > 0
    assert sum(share.values()) == pytest.approx(1.0)


def test_fault_goodput_is_attainment_over_survivors_only():
    template = chain_workflow(4, seed=11)
    configs = candidate_sets(template, 1, seed=2)[0]
    wf = template.copy()
    wf.apply_configs(configs)
    env = SimulatedPlatform().environment()
    rep = run_fleet(env, wf, PoissonArrivals(0.5, 24, seed=1), faults=FAULTS)
    slo = suggest_slo(template, slack=3.0)
    assert rep.failed_mask.any()
    assert rep.goodput(slo) == rep.slo_attainment(slo) <= 1.0
    assert rep.completion(slo) >= rep.goodput(slo)


def test_stranded_fault_work_error_names_uids_and_functions():
    err = _stranded_error([(3, "decode", False, False),
                           (1, "encode", False, True)])
    msg = str(err)
    assert "scheduler invariant violated" in msg
    assert "uid 1 fn 'encode'" in msg and "uid 3 fn 'decode'" in msg
    assert "failed=True" in msg


# -- validation --------------------------------------------------------

def test_fault_model_rejects_invalid_rates():
    with pytest.raises(ValueError):
        FaultModel(default_transient=1.5)
    with pytest.raises(ValueError):
        FaultModel(transient={"f": -0.1})
    with pytest.raises(ValueError):
        FaultModel(straggler_factor=0.5)
    with pytest.raises(ValueError):
        OutageWindow(node=0, start_s=5.0, end_s=5.0)


def test_retry_policy_rejects_invalid_knobs():
    with pytest.raises(ValueError):
        ResiliencePolicy(max_retries=MAX_ATTEMPTS)
    with pytest.raises(ValueError):
        ResiliencePolicy(timeout_s=0.0)
    with pytest.raises(ValueError):
        ResiliencePolicy(backoff_s=-1.0)
    with pytest.raises(ValueError):
        ResilienceSpec(retune_step=0.0)


# -- the policy ladder -------------------------------------------------

def test_retry_ladder_roundtrips_through_its_inverse():
    for level in range(6):
        pol = policy_ladder(level, 2.5, max_retries=3)
        assert ladder_level(pol, max_retries=3) == level
    assert policy_ladder(0, 2.5) is NO_RECOVERY
    top = policy_ladder(5, 2.0, max_retries=3, timeout_factor=4.0,
                        hedge_factor=2.0)
    assert top.max_retries == 3
    assert top.timeout_s == 8.0 and top.hedge_delay_s == 4.0


def test_grant_policies_target_the_highest_failure_share():
    sat = {"t/a": {"failed": 3}, "t/b": {"failed": 1}, "t/c": {"failed": 0}}
    out = grant_policies({"a": 0, "b": 0, "c": 0}, sat, width=2, max_level=5)
    assert out == {"a": 2, "b": 0, "c": 0}     # the whole width, ranked
    capped = grant_policies({"a": 5, "b": 0, "c": 0}, sat, width=1,
                            max_level=5)
    assert capped == {"a": 5, "b": 1, "c": 0}  # headroom-aware
    assert grant_policies({"a": 5, "b": 5, "c": 0}, sat, width=2,
                          max_level=5) == {"a": 5, "b": 5, "c": 0}


def test_degrade_policies_shed_off_critical_path_hedges():
    levels = {"a": 5, "b": 4, "c": 0}
    out = degrade_policies(levels, ["a"])
    assert out == {"a": 5, "b": 1, "c": 0}
    assert levels == {"a": 5, "b": 4, "c": 0}  # input untouched


# -- the searched policy -----------------------------------------------

def test_resilience_searcher_registry_and_feasibility():
    """``make_searcher("resilience", ...)`` searches recovery levels
    jointly with configs and reports a coherent result."""
    template = chain_workflow(3, seed=2)
    slo = suggest_slo(template, slack=3.0)
    spec = ResilienceSpec(
        faults=FaultModel(default_transient=0.1, seed=1),
        rate=0.5, n_instances=12, max_rounds=4, config_grant=16,
        target_attainment=0.8)
    searcher = make_searcher("resilience",
                             lambda: SimulatedPlatform().environment(),
                             spec=spec)
    result = searcher.search(template.copy(), slo)
    assert set(result.policies) <= set(template.nodes)
    for pol in result.policies.values():
        assert isinstance(pol, ResiliencePolicy)
    assert 0.0 <= result.fleet_attainment <= 1.0
    assert result.fleet_cost > 0.0 and result.fleet_evals > 0
    assert set(result.configs) == set(template.nodes)
    summary = result.summary()
    assert summary["fleet_attainment"] == result.fleet_attainment


# -- the online failure-bound actuator ---------------------------------

def _online_fault_spec(**kw):
    from repro.core.campaign import PortfolioSpec, ReplaySpec
    from repro.core.online import OnlineSpec
    faults = FaultModel(default_transient=0.15, straggler_prob=0.1,
                        straggler_factor=5.0, seed=11)
    base = dict(
        portfolio=PortfolioSpec(n_workflows=2, size=6, slo_slacks=(2.0,)),
        replay=ReplaySpec(n_instances=16, rate=0.5),
        n_epochs=4, seed=0, total_budget=256,
        faults=faults, resilience=ResilienceSpec(faults=faults))
    base.update(kw)
    return OnlineSpec(**base)


def test_online_failure_bound_misses_earn_retry_policy_grants():
    """Injected transients make epochs failure-bound; the controller
    answers with ladder grants (policy levels climb from zero) and the
    epoch rows carry the recovery ledgers."""
    from repro.core.online import run_online
    report = run_online(_online_fault_spec())
    rows = report.epochs
    assert rows
    for row in rows:
        assert {"failed", "fault_failures", "retries", "timeouts",
                "hedges"} <= set(row)
    assert any(row["fault_failures"] > 0 for row in rows)
    assert any(lvl > 0 for cell in report.cells
               for lvl in (cell.policy_levels or {}).values())
    payload = report.to_payload()
    assert "faults" in payload["spec"] and "resilience" in payload["spec"]


def test_online_fault_free_payload_has_no_fault_residue():
    """faults=None serving is the pinned pre-fault path: no fault keys
    anywhere in the payload, and two runs are byte-identical."""
    from repro.core.online import run_online
    spec = _online_fault_spec(faults=None, resilience=None)
    a = run_online(spec).to_payload()
    b = run_online(spec).to_payload()
    assert a == b
    assert "faults" not in a["spec"] and "resilience" not in a["spec"]
    for row in a["epochs"]:
        assert "failed" not in row and "retries" not in row
    for cell in a["cells"]:
        assert "policy_levels" not in cell


def test_online_resilience_without_faults_is_rejected():
    from repro.core.online import OnlineSpec
    with pytest.raises(ValueError):
        OnlineSpec(resilience=ResilienceSpec())
