"""Discrete-event fleet engine: degenerate-case parity, queuing,
cold starts, failure accounting."""
import math

import pytest

from repro.core.backend import CallableBackend
from repro.core.dag import Workflow
from repro.core.engine import (ClusterModel, ColdStartModel, FleetCarry,
                               FleetEngine, INFINITE_CLUSTER,
                               PoissonArrivals, TraceArrivals, run_fleet)
from repro.core.env import Environment, ExecutionError
from repro.core.resources import ResourceConfig
from repro.serverless.platform import SimulatedPlatform
from repro.serverless.workloads import WORKLOADS, chatbot, workload_slo

CLUSTER = ClusterModel(total_cpu=40.0, total_mem_mb=40960.0)


@pytest.mark.parametrize("name", list(WORKLOADS))
def test_fleet_of_one_matches_single_workflow_exactly(name):
    """Infinite capacity + zero cold start + fleet of 1 must reproduce
    the scalar-oracle end-to-end latency bit-for-bit."""
    wf_scalar = WORKLOADS[name]()
    e2e_scalar = wf_scalar.execute(SimulatedPlatform().oracle)

    wf_fleet = WORKLOADS[name]()
    env = SimulatedPlatform().environment()
    report = run_fleet(env, wf_fleet, [0.0], copy=False)
    res = report.instances[0]
    assert res.e2e == e2e_scalar                 # exact, not approx
    assert res.queue_delay == 0.0 and res.cold_delay == 0.0
    for node in wf_scalar:
        assert wf_fleet.nodes[node.name].runtime == node.runtime


@pytest.mark.parametrize("name", list(WORKLOADS))
def test_env_execute_routes_through_engine(name):
    """Environment.execute (used by AARC/BO/MAFF) is the degenerate
    fleet path and must agree with the scalar execution exactly."""
    e2e_scalar = WORKLOADS[name]().execute(SimulatedPlatform().oracle)
    env = SimulatedPlatform().environment()
    sample = env.execute(WORKLOADS[name](), slo=workload_slo(name))
    assert sample.e2e_runtime == e2e_scalar
    assert sample.feasible


def test_degenerate_fast_path_matches_event_loop():
    """The fleet-of-1 fast path must agree bit-for-bit with the full
    discrete-event loop (forced here via a finite-but-ample cluster)."""
    for mk in WORKLOADS.values():
        wf_fast, wf_event = mk(), mk()
        env = SimulatedPlatform().environment()
        fast = run_fleet(env, wf_fast, [0.0], copy=False)
        env = SimulatedPlatform().environment()
        event = run_fleet(env, wf_event, [0.0], copy=False,
                          cluster=ClusterModel(total_cpu=1e9,
                                               total_mem_mb=1e12))
        assert fast.instances[0].e2e == event.instances[0].e2e
        assert fast.instances[0].cost == pytest.approx(
            event.instances[0].cost)
        for node in wf_fast:
            assert node.runtime == wf_event.nodes[node.name].runtime


def test_percentiles_with_dead_instances_are_not_nan():
    """Dead instances (inf latency) must surface as inf in the tail,
    never as nan from interpolating between finite and inf."""
    def oracle(node):
        if node.payload == "bad":
            raise ExecutionError("dies")
        return 1.0

    def make(bad):
        wf = Workflow("bad" if bad else "ok")
        wf.add_function("f", payload="bad" if bad else None)
        return wf

    engine = FleetEngine(CallableBackend(oracle))     # no clamped => inf
    rep = engine.run([make(False), make(False), make(True)],
                     [0.0, 0.0, 0.0])
    assert rep.p50 == 1.0                 # median rank lands on a survivor
    assert math.isinf(rep.p99)            # tail crosses into the dead region
    assert not math.isnan(rep.p50) and not math.isnan(rep.p99)


def test_capacity_constrained_fleet_queues():
    """Acceptance scenario: 100 Poisson-arriving chatbot instances on a
    cluster smaller than aggregate demand => queuing delay > 0 and
    p99 > p50, while every instance still meets its work."""
    env = SimulatedPlatform().environment()
    report = run_fleet(env, chatbot(), PoissonArrivals(0.05, 100, seed=1),
                       cluster=CLUSTER)
    assert report.total_queue_delay > 0.0
    assert report.p99 > report.p50
    assert all(math.isfinite(r.e2e) for r in report.instances)
    assert 0.0 < report.cpu_utilization <= 1.0
    # per-function queue breakdown covers the queued functions
    assert sum(report.queue_delay_by_function.values()) == \
        pytest.approx(report.total_queue_delay)


def test_p99_monotone_in_arrival_rate():
    """Heavier traffic on the same cluster can only increase tail
    latency (same seeded service demands, compressed arrivals)."""
    p99s = []
    for rate in (0.02, 0.1, 0.5):
        env = SimulatedPlatform().environment()
        report = run_fleet(env, chatbot(), PoissonArrivals(rate, 60, seed=3),
                           cluster=CLUSTER)
        p99s.append(report.p99)
    assert p99s[0] <= p99s[1] <= p99s[2]
    assert p99s[2] > p99s[0]            # the effect is actually visible


def test_infinite_capacity_has_no_queuing():
    env = SimulatedPlatform().environment()
    report = run_fleet(env, chatbot(), PoissonArrivals(0.5, 40, seed=5))
    assert report.total_queue_delay == 0.0
    base = chatbot().execute(SimulatedPlatform().oracle)
    for r in report.instances:
        # (arrival + latency) - arrival re-rounds: exact equality is
        # only guaranteed for the arrival-at-0 degenerate path
        assert r.e2e == pytest.approx(base, rel=1e-12)


def test_cold_starts_add_latency_and_warm_reuse_removes_it():
    cold = ColdStartModel(delay_s=2.0, keep_alive_s=10_000.0)
    env = SimulatedPlatform().environment()
    # second instance arrives long after the first finished: all its
    # functions find warm containers
    report = run_fleet(env, chatbot(), TraceArrivals([0.0, 1000.0]),
                       cold_start=cold)
    first, second = report.instances
    assert first.cold_delay == pytest.approx(2.0 * len(chatbot()))
    assert second.cold_delay == 0.0
    assert first.e2e > second.e2e


def test_warm_containers_not_shared_across_unrelated_functions():
    """Heterogeneous fleets: a warm container belongs to (workflow
    template, function) — an unrelated function that happens to reuse
    a node name must still pay its own cold start."""
    from repro.serverless.generator import chain_workflow

    cold = ColdStartModel(delay_s=2.0, keep_alive_s=1e6)
    env = SimulatedPlatform().environment()
    # same node names (f000..), different templates (distinct specs)
    wfs = [chain_workflow(3, seed=1), chain_workflow(3, seed=2)]
    engine = FleetEngine(env.backend, pricing=env.pricing, cold_start=cold)
    report = engine.run(wfs, [0.0, 500.0])
    assert all(r.cold_delay == pytest.approx(6.0) for r in report.instances)
    # same template: the second instance DOES reuse warm containers
    env = SimulatedPlatform().environment()
    report = run_fleet(env, chain_workflow(3, seed=1),
                       TraceArrivals([0.0, 500.0]), cold_start=cold)
    assert report.instances[1].cold_delay == 0.0


def test_dead_release_unblocks_queued_work():
    """An invocation dying on the spot (inf runtime, full cluster) must
    free its capacity AND re-admit queued work at the same instant —
    the blocked instance runs instead of being reported as an instant
    no-op success."""
    def oracle(node):
        if node.payload == "bad":
            raise ExecutionError("dies")
        return 3.0

    wf_bad = Workflow("bad")
    wf_bad.add_function("f", payload="bad",
                        config=ResourceConfig(cpu=10.0, mem=10240.0))
    wf_ok = Workflow("ok")
    wf_ok.add_function("f", config=ResourceConfig(cpu=10.0, mem=10240.0))
    engine = FleetEngine(CallableBackend(oracle),     # no clamped => inf
                         cluster=ClusterModel(total_cpu=10.0,
                                              total_mem_mb=10240.0))
    report = engine.run([wf_bad, wf_ok], [0.0, 0.0])
    bad, ok = report.instances
    assert bad.failed and math.isinf(bad.e2e)
    assert not ok.failed and ok.e2e == 3.0            # actually executed
    assert wf_ok.nodes["f"].runtime == 3.0


def test_throughput_zero_for_dead_fleet():
    def oracle(node):
        raise ExecutionError("dies")

    wf = Workflow("w")
    wf.add_function("f")
    rep = FleetEngine(CallableBackend(oracle)).run([wf], [0.0])
    assert rep.throughput == 0.0


def test_expired_containers_are_cold_again():
    cold = ColdStartModel(delay_s=2.0, keep_alive_s=1.0)
    env = SimulatedPlatform().environment()
    report = run_fleet(env, chatbot(), TraceArrivals([0.0, 1000.0]),
                       cold_start=cold)
    assert report.instances[1].cold_delay == \
        pytest.approx(2.0 * len(chatbot()))


def test_failing_config_marks_instance_infeasible():
    wf = chatbot()
    wf.nodes["preprocess"].config = ResourceConfig(cpu=2.0, mem=128.0)  # OOM
    env = SimulatedPlatform().environment()
    report = run_fleet(env, wf, [0.0], copy=False)
    res = report.instances[0]
    assert res.failed
    assert math.isfinite(res.e2e)       # charged the clamped thrash time
    assert wf.nodes["preprocess"].failed
    assert "OOM" in wf.nodes["preprocess"].fail_reason
    assert report.slo_attainment(workload_slo("chatbot")) == 0.0
    # the diagnostic also reaches the search trace note
    sample = env.execute(wf, slo=workload_slo("chatbot"))
    assert sample.error and "OOM" in sample.note


def test_trace_arrivals_preserve_instance_pairing():
    """TraceArrivals must pair entry i with instance i, exactly like a
    raw float sequence (no silent re-sorting)."""
    from repro.core.engine import arrival_times

    assert arrival_times(TraceArrivals([5.0, 1.0])).tolist() == [5.0, 1.0]
    env = SimulatedPlatform().environment()
    rep = run_fleet(env, chatbot(), TraceArrivals([5.0, 1.0]))
    assert [r.arrival for r in rep.instances] == [5.0, 1.0]


def test_unplaceable_config_rejected():
    env = SimulatedPlatform().environment()
    with pytest.raises(ValueError, match="never be placed"):
        run_fleet(env, chatbot(), [0.0],
                  cluster=ClusterModel(total_cpu=1.0, total_mem_mb=1024.0))


def test_fifo_no_overtaking():
    """A later arrival must not start before an earlier one that is
    still waiting for capacity (strict FIFO admission)."""
    wf = chatbot()
    env = SimulatedPlatform().environment()
    # cluster fits exactly one base-config function at a time
    report = run_fleet(env, wf, TraceArrivals([0.0, 0.1, 0.2]),
                       cluster=ClusterModel(total_cpu=10.0,
                                            total_mem_mb=10240.0))
    by_arrival = sorted(report.instances, key=lambda r: r.arrival)
    finishes = [r.finish for r in by_arrival]
    assert finishes == sorted(finishes)


def test_engine_batches_invocations():
    """One engine step evaluates all simultaneously-started invocations
    in a single backend batch call."""
    calls = []
    platform = SimulatedPlatform()
    real = platform.backend.invoke_batch

    def spy(nodes):
        calls.append(len(nodes))
        return real(nodes)

    platform.backend.invoke_batch = spy
    engine = FleetEngine(platform.backend, pricing=platform.pricing)
    wfs = [chatbot() for _ in range(8)]
    engine.run(wfs, [0.0] * 8)
    # all 8 instances arrive at t=0: their sources start as ONE batch
    assert calls[0] == 8


# -- empty fleets (regression: was NaN percentiles/attainment) ---------

def test_empty_fleet_returns_well_defined_report():
    env = SimulatedPlatform().environment()
    engine = FleetEngine(env.backend, pricing=env.pricing)
    rep = engine.run([], [])
    assert rep.instances == []
    assert rep.makespan == 0.0 and rep.total_cost == 0.0
    assert rep.p50 == 0.0 and rep.p99 == 0.0
    assert rep.slo_attainment(1.0) == 1.0          # vacuous: nothing missed
    assert rep.throughput == 0.0
    assert not any(math.isnan(v) for v in
                   (rep.p50, rep.p99, rep.slo_attainment(1.0),
                    rep.cpu_utilization, rep.mem_utilization))
    # the run_fleet wrapper takes the same path
    rep = run_fleet(env, chatbot(), [])
    assert rep.instances == [] and rep.p99 == 0.0


def test_empty_fleet_passes_carry_through():
    env = SimulatedPlatform().environment()
    engine = FleetEngine(env.backend, pricing=env.pricing)
    carry = FleetCarry(clock=7.0, warm={("w", "f"): [[1.0, 100.0]]},
                       busy=[(9.0, 2.0, 512.0)])
    rep = engine.run([], [], carry=carry, collect_carry=True)
    assert rep.carry is not None
    assert rep.carry.warm == {("w", "f"): [[1.0, 100.0]]}
    assert rep.carry.busy == [(9.0, 2.0, 512.0)]


# -- resumable epoch runs (FleetCarry) ---------------------------------

def test_carry_keeps_containers_warm_across_epochs():
    """Epoch 1 resumed from epoch 0's carry reuses the warm pool; the
    same epoch served cold pays full provisioning again."""
    cold = ColdStartModel(delay_s=2.0, keep_alive_s=10_000.0)
    env = SimulatedPlatform().environment()
    engine = FleetEngine(env.backend, pricing=env.pricing, cold_start=cold)
    first = engine.run([chatbot()], [0.0], collect_carry=True)
    assert first.instances[0].cold_delay == pytest.approx(
        2.0 * len(chatbot()))
    resumed = engine.run([chatbot()], [500.0],
                         carry=first.carry.pruned(500.0))
    assert resumed.instances[0].cold_delay == 0.0
    fresh = engine.run([chatbot()], [500.0])
    assert fresh.instances[0].cold_delay == pytest.approx(
        2.0 * len(chatbot()))


def test_carry_busy_reservations_hold_capacity():
    """An invocation still running at the epoch boundary occupies its
    capacity in the next epoch until its finish time."""
    def oracle(node):
        return 10.0

    def one():
        wf = Workflow("w")
        wf.add_function("f", config=ResourceConfig(cpu=10.0, mem=10240.0))
        return wf

    engine = FleetEngine(CallableBackend(oracle),
                         cluster=ClusterModel(total_cpu=10.0,
                                              total_mem_mb=10240.0))
    first = engine.run([one()], [0.0], collect_carry=True)
    assert first.carry.busy == [(10.0, 10.0, 10240.0)]
    # boundary at t=5: the invocation (finishes at 10) is still running
    carry = first.carry.pruned(5.0)
    assert carry.busy == [(10.0, 10.0, 10240.0)]
    second = engine.run([one()], [5.0], carry=carry)
    res = second.instances[0]
    assert res.queue_delay == pytest.approx(5.0)   # waited until t=10
    assert res.finish == pytest.approx(20.0)
    # without the carry the same arrival would start immediately
    third = engine.run([one()], [5.0])
    assert third.instances[0].queue_delay == 0.0


def test_carry_pruning_drops_expired_and_finished_state():
    carry = FleetCarry(clock=0.0,
                       warm={("w", "a"): [[0.0, 10.0], [0.0, 100.0]],
                             ("w", "b"): [[0.0, 5.0]]},
                       busy=[(8.0, 1.0, 128.0), (50.0, 2.0, 256.0)])
    pruned = carry.pruned(20.0)
    assert pruned.clock == 20.0
    assert pruned.warm == {("w", "a"): [[0.0, 100.0]]}
    assert pruned.busy == [(50.0, 2.0, 256.0)]


def test_carry_chain_is_deterministic():
    """Serving two epochs via carry twice produces identical reports
    (the online control plane's epoch loop relies on this)."""
    cold = ColdStartModel(delay_s=1.0, keep_alive_s=30.0)

    def run_chain():
        env = SimulatedPlatform().environment()
        engine = FleetEngine(env.backend, pricing=env.pricing,
                             cluster=ClusterModel(total_cpu=50.0,
                                                  total_mem_mb=51200.0),
                             cold_start=cold)
        out = []
        carry = None
        for epoch in range(3):
            arrivals = PoissonArrivals(0.1, 8, seed=epoch,
                                       start=epoch * 80.0)
            wfs = [chatbot() for _ in range(8)]
            rep = engine.run(wfs, arrivals.times(), carry=carry,
                             collect_carry=True)
            carry = rep.carry.pruned((epoch + 1) * 80.0)
            out.append([(r.e2e, r.queue_delay, r.cold_delay, r.cost)
                        for r in rep.instances])
        return out

    assert run_chain() == run_chain()


# -- Environment.execute_function failure recording (env satellite) ----

def test_execute_function_failure_recorded_on_node():
    wf = chatbot()
    env = SimulatedPlatform().environment()
    env.execute(wf, slo=workload_slo("chatbot"))
    node = wf.nodes["preprocess"]
    good_runtime = node.runtime
    node.config = ResourceConfig(cpu=2.0, mem=128.0)          # below floor
    sample = env.execute_function(wf, node, slo=workload_slo("chatbot"))
    assert sample.error and not sample.feasible
    assert node.failed
    assert node.runtime != good_runtime       # stale runtime NOT kept
    assert node.runtime > 0 and math.isfinite(node.runtime)   # clamped


def test_execute_function_failure_without_clamped_is_infinite():
    def oracle(node):
        raise ExecutionError("always fails")

    wf = Workflow("w")
    node = wf.add_function("f")
    node.runtime = 1.23                       # stale value from earlier
    env = Environment(CallableBackend(oracle))
    sample = env.execute_function(wf, node, slo=10.0)
    assert sample.error
    assert node.failed
    assert math.isinf(node.runtime)           # failure visible in e2e
    assert math.isinf(wf.end_to_end_latency())
