"""Random-workflow generator: acyclicity, connectivity, seed
reproducibility, affinity profiles — plus the DAG's incremental
cycle-detection under adversarial edge orders."""
import time

import pytest

from repro.core.dag import Workflow
from repro.serverless.function import FunctionSpec
from repro.serverless.generator import (AFFINITY_PROFILES, GENERATORS,
                                        chain_workflow, diamond_workflow,
                                        fan_workflow, generate,
                                        layered_workflow, suggest_slo)
from repro.serverless.platform import SimulatedPlatform

KINDS = {
    "chain": dict(n=8),
    "fan": dict(width=5),
    "diamond": dict(n_diamonds=3),
    "layered": dict(n_nodes=24, n_layers=5, p_edge=0.3),
}


def _edges(wf: Workflow):
    return sorted((u, v) for u in wf.nodes for v in wf.successors(u))


def _on_source_sink_path(wf: Workflow):
    """Every node reachable from a source AND reaching a sink."""
    order = wf.topological_order()
    from_src = {n for n in wf.nodes if not wf.predecessors(n)}
    for n in order:
        if any(p in from_src for p in wf.predecessors(n)):
            from_src.add(n)
    to_sink = {n for n in wf.nodes if not wf.successors(n)}
    for n in reversed(order):
        if any(s in to_sink for s in wf.successors(n)):
            to_sink.add(n)
    return from_src & to_sink


@pytest.mark.parametrize("kind", list(KINDS))
def test_generated_workflows_are_valid_dags(kind):
    wf = generate(kind, seed=7, **KINDS[kind])
    order = wf.topological_order()          # raises on a cycle
    assert len(order) == len(wf)
    assert _on_source_sink_path(wf) == set(wf.nodes)
    for node in wf:
        assert isinstance(node.payload, FunctionSpec)


@pytest.mark.parametrize("kind", list(KINDS))
def test_generator_seed_reproducible(kind):
    a = generate(kind, seed=11, **KINDS[kind])
    b = generate(kind, seed=11, **KINDS[kind])
    c = generate(kind, seed=12, **KINDS[kind])
    assert list(a.nodes) == list(b.nodes)
    assert _edges(a) == _edges(b)
    for name in a.nodes:
        assert a.nodes[name].payload == b.nodes[name].payload
    # a different seed changes the response surfaces (and usually the
    # topology too)
    assert any(a.nodes[n].payload != c.nodes[n].payload
               for n in a.nodes if n in c.nodes) or _edges(a) != _edges(c)


def test_topology_shapes():
    assert len(chain_workflow(5)) == 5
    fan = fan_workflow(width=6)
    assert len(fan) == 8
    assert fan.sources() == ["scatter"] and fan.sinks() == ["gather"]
    dia = diamond_workflow(n_diamonds=2)
    assert len(dia) == 8
    assert len(dia.sources()) == 1 and len(dia.sinks()) == 1


def test_affinity_profile_pinning():
    wf = layered_workflow(12, n_layers=3, seed=4, profile="cpu_bound")
    lo, hi = AFFINITY_PROFILES["cpu_bound"].parallel_frac
    for node in wf:
        assert lo <= node.payload.parallel_frac <= hi


def test_large_layered_dag_builds_fast():
    """1k-node DAGs must build in linear-ish time (the add_edge cycle
    check is incremental, not a per-edge DFS)."""
    t0 = time.perf_counter()
    wf = layered_workflow(1000, n_layers=25, p_edge=0.08, seed=2)
    elapsed = time.perf_counter() - t0
    assert len(wf) == 1000
    assert elapsed < 5.0
    wf.validate()


def test_generated_workflow_runs_end_to_end():
    wf = layered_workflow(10, n_layers=4, seed=9)
    slo = suggest_slo(wf)
    env = SimulatedPlatform().environment()
    sample = env.execute(wf, slo=slo)
    assert sample.feasible
    assert sample.e2e_runtime <= slo


def test_generated_workflow_is_schedulable():
    """AARC's Graph-Centric Scheduler works on generated workflows
    through the same Environment API as the hand-built ones."""
    from repro.core.scheduler import GraphCentricScheduler

    wf = layered_workflow(8, n_layers=3, seed=21)
    slo = suggest_slo(wf, slack=2.0)
    env = SimulatedPlatform().environment()
    result = GraphCentricScheduler(env).schedule(wf, slo)
    assert result.e2e_runtime <= slo
    base_cost = env.trace.samples[0].cost
    assert result.cost < base_cost


# -- incremental cycle detection under adversarial edge orders ---------

def test_backward_edge_insertion_reorders_not_rejects():
    """Edges against the insertion order are legal as long as the graph
    stays acyclic (the Pearce–Kelly index reorders instead of failing)."""
    wf = Workflow("w")
    for name in "abcd":
        wf.add_function(name)
    wf.add_edge("d", "c")
    wf.add_edge("c", "b")
    wf.add_edge("b", "a")
    assert wf.topological_order() == ["d", "c", "b", "a"]
    with pytest.raises(ValueError, match="cycle"):
        wf.add_edge("a", "d")
    # the rejected edge must leave the graph untouched
    assert wf.successors("a") == ()
    assert wf.topological_order() == ["d", "c", "b", "a"]


def test_cycle_detected_through_long_path():
    wf = Workflow("w")
    names = [f"n{i}" for i in range(50)]
    for n in names:
        wf.add_function(n)
    wf.chain(*names)
    with pytest.raises(ValueError, match="cycle"):
        wf.add_edge(names[-1], names[0])
    with pytest.raises(ValueError, match="cycle"):
        wf.add_edge(names[10], names[10])
    wf.validate()


def test_copy_preserves_incremental_index():
    wf = diamond_workflow(n_diamonds=2, seed=1)
    cp = wf.copy()
    cp.validate()
    assert _edges(cp) == _edges(wf)
    with pytest.raises(ValueError, match="cycle"):
        cp.add_edge("d1_join", "d0_open")


# -- approximate topology matching (degree-sequence buckets) -----------

def test_degree_bucket_near_twin_donates():
    """Two layered DAGs of one (n_nodes, role-multiset) bucket donate
    configs by topological rank even though their exact edge sets
    differ — the warm-start fallback for layered portfolios."""
    from repro.core.resources import ResourceConfig
    from repro.serverless.generator import degree_bucket, transfer_configs
    from repro.serverless.generator import topology_signature

    src = layered_workflow(8, n_layers=3, seed=3)
    dst = layered_workflow(8, n_layers=3, seed=23)
    assert topology_signature(src) != topology_signature(dst)
    assert degree_bucket(src) == degree_bucket(dst)
    configs = {n.name: ResourceConfig(cpu=2.0, mem=2048.0) for n in src}
    with pytest.raises(ValueError, match="not structurally identical"):
        transfer_configs(src, configs, dst)
    moved = transfer_configs(src, configs, dst, approx=True)
    assert set(moved) == set(dst.nodes)
    assert all(c.cpu == 2.0 and c.mem == 2048.0 for c in moved.values())


def test_degree_bucket_rejects_structurally_distant_workflows():
    """A chain and a fan of the same node count are different role
    multisets — approximate matching must NOT cross families."""
    from repro.core.resources import ResourceConfig
    from repro.serverless.generator import degree_bucket, transfer_configs

    src = chain_workflow(6, seed=0)
    dst = fan_workflow(4, seed=1)          # also 6 nodes
    assert len(src) == len(dst)
    assert degree_bucket(src) != degree_bucket(dst)
    configs = {n.name: ResourceConfig(cpu=2.0, mem=2048.0) for n in src}
    with pytest.raises(ValueError, match="not structurally similar"):
        transfer_configs(src, configs, dst, approx=True)


# -- drift schedules (the online control plane's disturbance source) ----

def test_drift_schedule_steps_conditions_by_epoch():
    from repro.serverless.generator import DriftEvent, DriftSchedule

    sched = DriftSchedule((DriftEvent(4, "input", 1.5),
                           DriftEvent(2, "load", 3.0),
                           DriftEvent(6, "coldstart", 2.0,
                                      keep_alive_s=30.0)))
    assert sched.conditions(0).baseline
    assert sched.conditions(1).baseline
    c2 = sched.conditions(2)
    assert c2.rate_scale == 3.0 and c2.input_scale == 1.0
    c5 = sched.conditions(5)
    assert c5.rate_scale == 3.0 and c5.input_scale == 1.5
    assert c5.cold_delay_s is None
    c6 = sched.conditions(6)
    assert c6.cold_delay_s == 2.0 and c6.cold_keep_alive_s == 30.0
    # regime counts events in effect: re-arms the online detector
    assert [sched.regime(e) for e in range(7)] == [0, 0, 1, 1, 2, 2, 3]


def test_drift_schedule_empty_is_baseline_everywhere():
    from repro.serverless.generator import DriftSchedule

    sched = DriftSchedule()
    assert sched.empty
    assert all(sched.conditions(e).baseline for e in range(10))
    assert all(sched.regime(e) == 0 for e in range(10))


def test_drift_event_validation():
    from repro.serverless.generator import DriftEvent

    with pytest.raises(ValueError, match="unknown drift kind"):
        DriftEvent(1, "weather", 2.0)
    with pytest.raises(ValueError, match="epoch"):
        DriftEvent(-1, "load", 2.0)
    with pytest.raises(ValueError, match="magnitude"):
        DriftEvent(1, "load", -2.0)
    # a zero rate/input multiplier would only crash the serving loop
    # mid-epoch — rejected at construction instead
    with pytest.raises(ValueError, match="must be > 0"):
        DriftEvent(1, "load", 0.0)
    with pytest.raises(ValueError, match="must be > 0"):
        DriftEvent(1, "input", 0.0)
    assert DriftEvent(1, "coldstart", 0.0).magnitude == 0.0  # legal regime


def test_random_drift_schedule_is_seeded_and_bounded():
    from repro.serverless.generator import random_drift_schedule

    a = random_drift_schedule(10, seed=7, n_events=3,
                              kinds=("load", "input"))
    b = random_drift_schedule(10, seed=7, n_events=3,
                              kinds=("load", "input"))
    c = random_drift_schedule(10, seed=8, n_events=3,
                              kinds=("load", "input"))
    assert a == b
    assert a != c
    assert len(a.events) == 3
    assert all(1 <= e.epoch < 10 for e in a.events)
    assert {e.kind for e in a.events} <= {"load", "input"}
    assert random_drift_schedule(1, seed=0).empty
