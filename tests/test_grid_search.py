"""Lockstep grid-search plane: bit-identity and eligibility.

Pins the vectorized campaign search plane's contract
(:func:`repro.core.search.run_grid_search`):

  * per-cell traces are **bit-identical** to the sequential
    ``Searcher.search`` / ``resume`` loops — across topologies,
    searchers, analytic and stochastic backends, tight and loose SLOs
    (tight slack drives OOM/error samples through the fused failure
    branches), and duplicate-seed cells that share one structure
    group,
  * ineligible cells serialize with explainable reasons
    (mirroring ``FleetEngine.batch_eligibility``) and still return
    their plain sequential results,
  * the Algorithm-2 batch-size crossover (scalar invokes for narrow
    rounds) commits the same trials as the batched probe path on
    deterministic backends,
  * ``BENCH_campaign.json`` rows carry no wall-clock-derived keys.
"""
import itertools

import pytest

from repro.core.campaign import _build_workflow
from repro.core.priority import priority_configuration
from repro.core.resources import BASE_CONFIG
from repro.core.search import (GridCell, GridResume, grid_eligibility,
                               make_searcher, run_grid_search)
from repro.serverless.generator import suggest_slo
from repro.serverless.platform import make_env

KINDS = ("chain", "fan", "diamond", "layered")
SEARCHER_KWARGS = {"aarc": {"batch_size": 4},
                   "bo": {"n_rounds": 6, "n_init": 8, "batch_size": 4},
                   "maff": {}}


def _key(sample):
    return (sample.e2e_runtime, sample.cost, sample.feasible, sample.error,
            sample.trial_time, sample.note,
            tuple(sample.config_items or ()))


def _make_cell(kind, sname, sigma, slack, seed):
    wf = _build_workflow(kind, 8, seed)
    env = make_env(noise_sigma=sigma, seed=1000 + seed)
    searcher = make_searcher(sname, lambda e=env: e,
                             **SEARCHER_KWARGS[sname])
    return env, searcher, wf, suggest_slo(wf, slack=slack)


def _grid_specs(sigma):
    specs = []
    for kind, sname, slack in itertools.product(
            KINDS, sorted(SEARCHER_KWARGS), [1.05, 2.0]):
        specs.append((kind, sname, sigma, slack, 7))
        if kind == "chain" and slack == 1.05:
            # duplicate-seed cells: identical workflows share one
            # structure group in the fused commit folds
            specs.append((kind, sname, sigma, slack, 7))
            specs.append((kind, sname, sigma, slack, 11))
    return specs


@pytest.mark.parametrize("sigma", [0.0, 0.05],
                         ids=["analytic", "stochastic"])
def test_grid_traces_bit_identical_to_sequential(sigma):
    specs = _grid_specs(sigma)

    seq_traces, seq_invocations = [], []
    for spec in specs:
        env, searcher, wf, slo = _make_cell(*spec)
        res = searcher.search(wf, slo)
        seq_traces.append([_key(s) for s in res.trace.samples])
        seq_invocations.append(env.backend.invocations)
    # tight slack must exercise the fused failure branches
    assert any(k[3] for trace in seq_traces for k in trace)  # k[3]=error

    envs, cells = [], []
    for spec in specs:
        env, searcher, wf, slo = _make_cell(*spec)
        envs.append(env)
        cells.append((searcher, wf, slo))
    report = run_grid_search(cells)

    assert report.serialized_cells == 0
    assert all(e.eligible for e in report.eligibility)
    assert report.fused_evaluations > 0
    for i, res in enumerate(report.results):
        assert [_key(s) for s in res.trace.samples] == seq_traces[i], \
            f"trace diverged for cell {specs[i]}"
        assert envs[i].backend.invocations == seq_invocations[i], \
            f"invocation count diverged for cell {specs[i]}"


@pytest.mark.parametrize("sname", sorted(SEARCHER_KWARGS))
def test_grid_resume_bit_identical_to_sequential(sname):
    extra = 8

    env_s, searcher_s, wf_s, slo = _make_cell("chain", sname, 0.0, 1.2, 7)
    first = searcher_s.search(wf_s, slo)
    resumed = searcher_s.resume(first.state, extra)
    seq_trace = [_key(s) for s in resumed.trace.samples]

    env_g, searcher_g, wf_g, _ = _make_cell("chain", sname, 0.0, 1.2, 7)
    first_g = run_grid_search([(searcher_g, wf_g, slo)]).results[0]
    report = run_grid_search(
        [GridResume(searcher=searcher_g, state=first_g.state,
                    extra_budget=extra)])
    grid_trace = [_key(s) for s in report.results[0].trace.samples]

    assert grid_trace == seq_trace
    assert env_g.backend.invocations == env_s.backend.invocations


class _OpaqueSearcher:
    """A searcher without ``plan()`` — no lockstep support."""

    name = "opaque"

    def __init__(self, inner):
        self._inner = inner

    def search(self, wf, slo):
        return self._inner.search(wf, slo)


def test_mixed_eligibility_serializes_with_reasons():
    env_a, searcher_a, wf_a, slo_a = _make_cell("chain", "maff", 0.0, 1.2, 7)
    env_b, searcher_b, wf_b, slo_b = _make_cell("fan", "maff", 0.0, 1.2, 8)

    # two cells on ONE Environment interleave a single trace: serialize
    shared_env, _, _, _ = _make_cell("chain", "maff", 0.0, 1.2, 9)
    shared_1 = make_searcher("maff", lambda: shared_env)
    shared_2 = make_searcher("maff", lambda: shared_env)
    wf_s1 = _build_workflow("chain", 8, 9)
    wf_s2 = _build_workflow("chain", 8, 10)

    env_o, _, wf_o, slo_o = _make_cell("diamond", "maff", 0.0, 1.2, 11)
    opaque = _OpaqueSearcher(make_searcher("maff", lambda e=env_o: e))

    cells = [
        (searcher_a, wf_a, slo_a),
        (shared_1, wf_s1, suggest_slo(wf_s1, slack=1.2)),
        (shared_2, wf_s2, suggest_slo(wf_s2, slack=1.2)),
        GridCell(searcher=opaque, wf=wf_o, slo=slo_o),
        (searcher_b, wf_b, slo_b),
    ]

    # the dry run reports without sampling
    dry = grid_eligibility(cells)
    assert [e.eligible for e in dry] == [True, False, False, False, True]
    assert env_a.backend.invocations == 0

    report = run_grid_search(cells)
    assert [e.eligible for e in report.eligibility] == \
        [True, False, False, False, True]
    assert report.serialized_cells == 3
    assert any("Environment" in r for r in report.eligibility[1].reasons)
    assert any("plan" in r for r in report.eligibility[3].reasons)

    # serialized cells still return their plain sequential result
    env_ref, _, _, _ = _make_cell("diamond", "maff", 0.0, 1.2, 11)
    ref = make_searcher("maff", lambda e=env_ref: e).search(
        _build_workflow("diamond", 8, 11), slo_o)
    got = report.results[3]
    assert [_key(s) for s in got.trace.samples] == \
        [_key(s) for s in ref.trace.samples]


def test_priority_crossover_matches_probe_path():
    """Narrow rounds served by scalar invokes (the batch-size
    crossover) commit the identical trial sequence the batched probe
    path would — pinned by forcing the threshold to zero."""
    def run(scalar_round_max):
        wf = _build_workflow("layered", 12, 3)
        env = make_env(seed=42)
        if scalar_round_max is not None:
            env.backend.scalar_round_max = scalar_round_max
        for node in wf:
            node.config = BASE_CONFIG.copy()
        wf.execute(env.oracle)
        path = [node.name for node in wf]
        slo = suggest_slo(wf, slack=1.3)
        priority_configuration(wf, path, slo, env, batch_size=8)
        return [_key(s) for s in env.trace.samples]

    assert run(None) == run(0)      # backend default vs probe-only


def test_bench_campaign_payload_is_timing_free():
    from benchmarks.campaign_scale import deterministic_payload

    row = {"case": "grid_search_batch", "n_cells": 96,
           "traces_identical": True, "wall_s": 1.0,
           "sequential_wall_s": 3.0, "grid_wall_s": 1.0,
           "grid_cells_per_s": 96.0, "grid_speedup": 3.0,
           "probe_wall_ratio": 1.1}
    assert deterministic_payload(row) == {
        "case": "grid_search_batch", "n_cells": 96,
        "traces_identical": True}
