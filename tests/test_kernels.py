"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in
interpret mode (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import fused_rmsnorm
from repro.kernels.rmsnorm.ref import fused_rmsnorm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_naive, ssd_scan_ref

TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-4),
       jnp.bfloat16: dict(atol=6e-2, rtol=6e-2)}


@pytest.mark.parametrize("b,s,h,hkv,d", [
    (2, 256, 4, 2, 64),
    (1, 512, 8, 2, 128),
    (2, 128, 4, 4, 32),
    (1, 256, 6, 1, 64),          # MQA extreme
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, hkv, d, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, hkv, d), dtype)
    out = flash_attention(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("block_q,block_kv", [(64, 64), (128, 64),
                                              (64, 128)])
def test_flash_attention_block_shapes(block_q, block_kv):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out = flash_attention(q, k, v, block_q=block_q, block_kv=block_kv,
                          interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **TOL[jnp.float32])


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 256, 8, 64, 64, 128),
    (2, 64, 2, 16, 8, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, s, h, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.key(2), 5)
    xh = jax.random.normal(ks[0], (b, s, h, p), dtype)
    bm = jax.random.normal(ks[1], (b, s, n), dtype)
    cm = jax.random.normal(ks[2], (b, s, n), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    log_a = -dt * jnp.exp(jax.random.normal(ks[4], (b, s, h)) * 0.3)
    y, hf = ssd_scan(xh, bm, cm, log_a, dt, chunk=chunk, interpret=True)
    yr, hr = ssd_scan_ref(xh, bm, cm, log_a, dt, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr),
                               atol=1e-3, rtol=1e-2)


def test_ssd_chunked_ref_matches_naive_recurrence():
    """The chunked reference itself is validated against the O(s)
    per-token recurrence (ground-truth SSD semantics)."""
    ks = jax.random.split(jax.random.key(3), 5)
    b, s, h, p, n = 2, 96, 2, 16, 8
    xh = jax.random.normal(ks[0], (b, s, h, p))
    bm = jax.random.normal(ks[1], (b, s, n))
    cm = jax.random.normal(ks[2], (b, s, n))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    log_a = -dt * jnp.exp(jax.random.normal(ks[4], (b, s, h)) * 0.3)
    yr, hr = ssd_scan_ref(xh, bm, cm, log_a, dt, chunk=32)
    yn, hn = ssd_scan_naive(xh, bm, cm, log_a, dt)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yn),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hr), np.asarray(hn),
                               atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("shape", [(2, 64, 128), (4, 100, 256), (512, 384),
                                   (1, 7, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rmsnorm_sweep(shape, dtype):
    ks = jax.random.split(jax.random.key(4), 3)
    x = jax.random.normal(ks[0], shape, dtype)
    r = jax.random.normal(ks[1], shape, dtype)
    w = jax.random.normal(ks[2], shape[-1:], dtype)
    y, nr = fused_rmsnorm(x, r, w, interpret=True)
    yr, nrr = fused_rmsnorm_ref(x, r, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(nr, np.float32),
                               np.asarray(nrr, np.float32), **TOL[dtype])


def test_model_attention_paths_agree():
    """attn_impl='pallas_interpret' equals the XLA path in the full
    model (block-level integration of the kernel)."""
    from repro.configs.registry import reduced_config
    from repro.models.model import Model
    cfg_x = reduced_config("olmo-1b")
    cfg_p = reduced_config("olmo-1b", attn_impl="pallas_interpret")
    mx, mp = Model(cfg_x), Model(cfg_p)
    params = mx.init(jax.random.key(5))
    batch = {"tokens": jax.random.randint(jax.random.key(6), (2, 64), 0,
                                          cfg_x.vocab)}
    lx, _ = mx.forward(params, {**batch, "labels": batch["tokens"]})
    lp, _ = mp.forward(params, {**batch, "labels": batch["tokens"]})
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               atol=2e-4, rtol=2e-3)


def test_mamba_kernel_path_in_model():
    from repro.configs.registry import reduced_config
    from repro.models.model import Model
    cfg_x = reduced_config("zamba2-1.2b")
    cfg_k = reduced_config("zamba2-1.2b", use_ssm_kernel=True,
                           attn_impl="pallas_interpret")
    mx, mk = Model(cfg_x), Model(cfg_k)
    params = mx.init(jax.random.key(7))
    batch = {"tokens": jax.random.randint(jax.random.key(8), (2, 64), 0,
                                          cfg_x.vocab)}
    lx, _ = mx.forward(params, {**batch, "labels": batch["tokens"]})
    lk, _ = mk.forward(params, {**batch, "labels": batch["tokens"]})
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lk),
                               atol=2e-4, rtol=2e-3)
