"""Per-arch smoke tests (assignment requirement): instantiate a REDUCED
same-family config, run one forward + one train step on CPU, assert
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS
from repro.configs.registry import get_config, reduced_config
from repro.models.model import Model
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_step import make_train_step


def batch_for(cfg, b=2, s=32, key=None):
    key = key if key is not None else jax.random.key(0)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model), cfg.jdtype)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model), cfg.jdtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    batch = batch_for(cfg)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = reduced_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    state = adamw_init(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    state, metrics = step(state, batch_for(cfg))
    assert int(state["step"]) == 1
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    changed = jax.tree.map(lambda a, b: bool((a != b).any()),
                           params, state["params"])
    assert any(jax.tree.leaves(changed)), f"{arch}: no param updated"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_is_well_formed(arch):
    """The FULL configs are exercised via the dry-run only — here we
    validate their static invariants without allocating."""
    cfg = get_config(arch)
    assert cfg.padded_vocab % 256 == 0
    assert cfg.padded_vocab >= cfg.vocab
    if cfg.family == "vlm":
        assert cfg.n_layers % cfg.cross_attn_every == 0
    if cfg.family == "hybrid":
        assert cfg.ssm is not None
    if cfg.family == "moe":
        assert cfg.moe.n_experts >= cfg.moe.top_k
    # abstract params materialize nothing and have a consistent axes tree
    model = Model(cfg)
    specs, axes = model.abstract_params()
    ns, na = len(jax.tree.leaves(specs)), 0
    from repro.models.transformer import is_axes_leaf
    na = len(jax.tree.leaves(axes, is_leaf=is_axes_leaf))
    assert ns == na, f"{arch}: axes tree mismatch ({ns} vs {na})"
