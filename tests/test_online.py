"""Property tests for the online serving control plane.

Four invariants the control plane must hold for *any* spec:

  * **ledger conservation** — the grant budget satisfies
    ``allocated == spent + remaining`` across epochs, every grant in
    the reconfiguration ledger is accounted, and no grant overdraws
    its per-round budget;
  * **challenger gating** — a reconfiguration never *lowers* a cell's
    validated attainment: rejected challengers keep the incumbent,
    accepted ones validated strictly better (or equal at lower cost);
  * **determinism** — everything derives from the master seed, so two
    runs of one spec produce identical payloads
    (``BENCH_online.json`` content, wall-clock excluded);
  * **static equivalence** — with an empty
    :class:`repro.serverless.generator.DriftSchedule`, a ``"drift"``
    run serves bit-identically to a ``"never"`` (configure-once) run:
    the detector stays silent and the serving loop is shared code.
"""
import dataclasses
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.campaign import PortfolioSpec, ReplaySpec
from repro.core.engine import ClusterModel
from repro.core.online import OnlineSpec, run_online
from repro.serverless.generator import (DriftSchedule, input_mix_schedule,
                                        load_shift_schedule)


def _drift_spec(seed=0, total_budget=256, **kw):
    """A small spec whose input-mix drift reliably collapses the static
    fleet, so detection and grants actually fire."""
    base = dict(
        portfolio=PortfolioSpec(n_workflows=2, size=6, slo_slacks=(2.0,)),
        replay=ReplaySpec(n_instances=16, rate=0.5),
        n_epochs=6, drift=input_mix_schedule(2, 1.5),
        seed=seed, total_budget=total_budget)
    base.update(kw)
    return OnlineSpec(**base)


#: a finite-quota regime where load drift produces queueing (carry and
#: busy reservations in play, unlike the infinite-cluster spec above)
_CONTENDED = ReplaySpec(n_instances=16, rate=0.1,
                        cluster=ClusterModel(total_cpu=460.0,
                                             total_mem_mb=460.0 * 1024.0))


# -- ledger conservation ------------------------------------------------

@given(st.integers(0, 10_000), st.integers(8, 400), st.integers(4, 24))
@settings(max_examples=6, deadline=None)
def test_grant_ledger_is_conserved_across_epochs(seed, total_budget,
                                                 grant_budget):
    """allocated == spent + remaining for any budget, and the
    reconfiguration ledger accounts every sample the grants consumed."""
    report = run_online(_drift_spec(seed=seed, total_budget=total_budget,
                                    grant_budget=grant_budget))
    b = report.budget
    assert b["total"] == b["spent"] + b["remaining"]
    assert b["total"] == report.spec.total_budget
    assert b["spent"] == sum(c.spent for c in report.cells)
    assert b["spent"] == sum(r.spent for r in report.reconfigs)
    for record in report.reconfigs:
        assert record.granted <= grant_budget
        assert record.spent <= record.granted


def test_deploy_spend_stays_out_of_the_grant_ledger():
    report = run_online(_drift_spec())
    assert report.deploy_spent > 0
    assert report.budget["spent"] == sum(r.spent for r in report.reconfigs)


def test_every_epoch_mode_records_realized_spend():
    report = run_online(_drift_spec(mode="every_epoch", n_epochs=3))
    b = report.budget
    assert b["total"] == b["spent"] + b["remaining"]
    assert b["remaining"] == 0
    # one full re-search per cell per post-deploy epoch
    assert all(c.grants == report.spec.n_epochs - 1 for c in report.cells)
    assert b["spent"] > 0


def test_exhausted_budget_stops_grants():
    tiny = run_online(_drift_spec(total_budget=8, grant_budget=8))
    assert tiny.budget["spent"] <= 8
    assert tiny.budget["total"] == tiny.budget["spent"] + \
        tiny.budget["remaining"]


# -- challenger gating ---------------------------------------------------

@given(st.integers(0, 10_000), st.booleans())
@settings(max_examples=6, deadline=None)
def test_no_reconfiguration_lowers_validated_attainment(seed, contended):
    """The swap gate: every ledger entry keeps validated attainment at
    least at the incumbent's level; accepted swaps validated strictly
    better (or equal attainment at strictly lower fleet cost)."""
    spec = _drift_spec(seed=seed)
    if contended:
        spec = dataclasses.replace(spec, replay=_CONTENDED,
                                   drift=load_shift_schedule(2, 3.0))
    report = run_online(spec)
    for r in report.reconfigs:
        assert r.validated_after >= r.validated_before - 1e-12
        if r.accepted:
            assert (r.validated_after > r.validated_before
                    or r.cost_after < r.cost_before)
        else:
            assert r.validated_after == r.validated_before
            assert r.cost_after == r.cost_before


def test_drift_recovery_beats_static_fleet():
    """The acceptance property at test scale: under input-mix drift the
    control plane recovers what the static fleet loses."""
    spec = _drift_spec()
    online = run_online(spec)
    static = run_online(dataclasses.replace(spec, mode="never"))
    oa, sa = online.epoch_attainment(), static.epoch_attainment()
    # static collapses after the drift epoch; online recovers
    assert sa[-1] < sa[0] - 0.5
    assert oa[-1] > sa[-1] + 0.5
    assert online.budget["spent"] > 0
    assert any(r.accepted for r in online.reconfigs)


# -- determinism ---------------------------------------------------------

@given(st.integers(0, 10_000), st.booleans())
@settings(max_examples=4, deadline=None)
def test_payload_is_deterministic(seed, contended):
    """Two runs of one master seed emit identical payloads — including
    when drift detection and reconfiguration fire."""
    spec = _drift_spec(seed=seed)
    if contended:
        spec = dataclasses.replace(spec, replay=_CONTENDED,
                                   drift=load_shift_schedule(2, 3.0))
    first = run_online(spec).to_payload()
    second = run_online(spec).to_payload()
    assert first == second


def test_bench_row_is_deterministic():
    """The emitted BENCH_online.json rows (minus wall-clock keys) are
    identical across runs of the same master seed."""
    bench = pytest.importorskip(
        "benchmarks.online_serving",
        reason="benchmarks namespace needs the repo root on sys.path")
    # enough epochs that the post-drift window sits past convergence
    spec = _drift_spec(n_epochs=8)
    first = bench.deterministic_payload(bench.drift_case("t", spec))
    second = bench.deterministic_payload(bench.drift_case("t", spec))
    assert first == second
    assert not any(k == "wall_s" for k in first)
    assert first["recovery"] >= 0.8
    assert first["probe_fraction"] <= 0.5


# -- static-fleet equivalence -------------------------------------------

@given(st.integers(0, 10_000))
@settings(max_examples=4, deadline=None)
def test_empty_drift_schedule_is_bit_identical_to_static_replay(seed):
    """With no drift, the control plane IS the static fleet: same
    serving rows (bit-identical floats), silent detector, zero spend."""
    spec = _drift_spec(seed=seed, drift=DriftSchedule(),
                       replay=_CONTENDED)
    online = run_online(spec).to_payload()
    static = run_online(
        dataclasses.replace(spec, mode="never")).to_payload()
    assert online["epochs"] == static["epochs"]
    assert online["epoch_attainment"] == static["epoch_attainment"]
    assert online["reconfigs"] == [] and static["reconfigs"] == []
    assert online["budget"]["spent"] == 0
    # the serving configs never moved off the deploy-time configuration
    for c_on, c_st in zip(online["cells"], static["cells"]):
        assert c_on["configs"] == c_st["configs"]


# -- report shape --------------------------------------------------------

def test_payload_covers_cells_epochs_and_ledger():
    spec = _drift_spec()
    payload = run_online(spec).to_payload()
    assert len(payload["cells"]) == 2
    assert len(payload["epochs"]) == 2 * spec.n_epochs
    assert len(payload["epoch_attainment"]) == spec.n_epochs
    assert {"total", "spent", "remaining"} == set(payload["budget"])
    for row in payload["epochs"]:
        assert {"epoch", "cell", "attainment", "p99_s", "cost",
                "queue_delay_s", "cold_delay_s", "rate_scale",
                "input_scale"} <= set(row)
    for row in payload["reconfigs"]:
        assert {"epoch", "cell", "granted", "spent", "accepted",
                "validated_before", "validated_after",
                "effective_slo"} <= set(row)
    assert 0.0 <= payload["mean_attainment"] <= 1.0
    assert math.isfinite(payload["mean_attainment"])


def test_unknown_mode_rejected():
    with pytest.raises(ValueError, match="unknown mode"):
        OnlineSpec(mode="sometimes")


def test_grant_budget_must_fund_retune_plus_search():
    with pytest.raises(ValueError, match="grant_budget"):
        OnlineSpec(grant_budget=1)


def test_cooldown_enforces_a_real_sit_out():
    """A granted cell sits out cooldown_epochs grant phases: with the
    default cooldown of 1, two grants to one cell are >= 2 epochs
    apart (regression: the decrement used to land in the same epoch
    the grant set it, making the cooldown a no-op)."""
    spec = OnlineSpec(
        portfolio=PortfolioSpec(n_workflows=3, size=6, slo_slacks=(2.0,)),
        replay=ReplaySpec(n_instances=16, rate=0.1,
                          cluster=ClusterModel(total_cpu=200.0,
                                               total_mem_mb=200.0 * 1024.0)),
        n_epochs=10, drift=load_shift_schedule(2, 3.0), seed=0,
        total_budget=256)
    report = run_online(spec)
    by_cell = {}
    for r in report.reconfigs:
        by_cell.setdefault(r.cell, []).append(r.epoch)
    assert any(len(v) > 1 for v in by_cell.values()), \
        "scenario must re-grant at least one cell"
    for epochs in by_cell.values():
        assert all(b - a >= spec.cooldown_epochs + 1
                   for a, b in zip(epochs, epochs[1:])), epochs


def test_windows_reset_on_regime_change_and_swap():
    """After a drift event enters a new regime, stale-regime
    observations are dropped (the detector re-arms); after an accepted
    swap the estimator restarts for the new configuration."""
    report = run_online(_drift_spec())
    drift_epoch = report.spec.drift.events[0].epoch
    swaps = [r for r in report.reconfigs if r.accepted]
    assert swaps, "the drift spec must force at least one swap"
    # every swap happened at or after the regime change
    assert all(r.epoch >= drift_epoch for r in swaps)
    for cell in report.cells:
        # windows only hold post-swap observations, bounded by maxlen
        assert len(cell.window) <= report.spec.window
