"""Numerical equivalence of the §Perf hillclimb features: they must
change *where bytes move*, never *what is computed*."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import MoEConfig, apply_moe, make_moe_params


def _x(key, b=2, s=16, d=64):
    return jax.random.normal(key, (b, s, d))


def test_grouped_dispatch_matches_global_dropfree():
    cfg_g = MoEConfig(n_experts=8, top_k=2, expert_ff=32, shared_ff=64,
                      capacity_factor=8.0, dispatch="global")
    cfg_l = dataclasses.replace(cfg_g, dispatch="grouped")
    params, _ = make_moe_params(jax.random.key(0), 64, cfg_g, jnp.float32)
    x = _x(jax.random.key(1))
    yg, ag = apply_moe(params, x, cfg_g)
    yl, al = apply_moe(params, x, cfg_l)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yl), atol=1e-6)
    np.testing.assert_allclose(float(ag), float(al), atol=1e-6)


def test_expert_padding_is_bit_exact():
    """EP padding (dead experts masked to -inf) never changes outputs."""
    cfg_p = MoEConfig(n_experts=6, top_k=2, expert_ff=32,
                      capacity_factor=8.0, pad_to=8)
    params_p, _ = make_moe_params(jax.random.key(0), 64, cfg_p,
                                  jnp.float32)
    x = _x(jax.random.key(1))
    yp, _ = apply_moe(params_p, x, cfg_p)
    cfg_u = dataclasses.replace(cfg_p, pad_to=0)
    params_u = {k: (v[:, :6] if k == "router" else v[:6])
                for k, v in params_p.items()}
    yu, _ = apply_moe(params_u, x, cfg_u)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yu), atol=1e-6)


def test_padded_experts_receive_no_tokens():
    cfg = MoEConfig(n_experts=6, top_k=2, expert_ff=32,
                    capacity_factor=8.0, pad_to=8)
    params, _ = make_moe_params(jax.random.key(0), 64, cfg, jnp.float32)
    from repro.models.moe import _routing
    x = _x(jax.random.key(1))
    routing, probs, top_idx = _routing(params, x.reshape(-1, 64), cfg)
    assert int(top_idx.max()) < 6, "router selected a dead expert"
    assert float(routing[:, 6:].sum()) == 0.0


def test_gqa_expand_path_matches_grouped_path():
    """The head-sharded (repeat) attention path == the grouped path."""
    from repro.models import attention as A
    ks = jax.random.split(jax.random.key(2), 3)
    b, s, h, hkv, d = 2, 256, 8, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    out_expand = A._sdpa_xla(q, k, v, causal=True)  # s>=128: repeat path
    # force the grouped path by lowering the threshold
    old = A.GQA_EXPAND_MIN_SQ
    A.GQA_EXPAND_MIN_SQ = 10_000
    try:
        out_grouped = A._sdpa_xla(q, k, v, causal=True)
    finally:
        A.GQA_EXPAND_MIN_SQ = old
    np.testing.assert_allclose(np.asarray(out_expand),
                               np.asarray(out_grouped), atol=2e-5,
                               rtol=1e-4)


def test_sp_rules_shard_scores_over_seq_when_heads_dont_divide():
    """B3: with act_seq->model, 36 heads fall through to seq sharding."""
    from repro.distributed.sharding import FSDP_RULES

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rules = FSDP_RULES.override(act_seq="model")
    spec = rules.spec(("batch", "heads_act", "act_seq", None),
                      (32, 36, 4096, 4096), FakeMesh())
    import jax as _jax
    assert spec == _jax.sharding.PartitionSpec("data", None, "model")
    # heads win when they divide (llama: 64)
    spec2 = rules.spec(("batch", "heads_act", "act_seq", None),
                       (32, 64, 4096, 4096), FakeMesh())
    assert spec2 == _jax.sharding.PartitionSpec("data", "model")


def test_int8_kv_cache_decode_accuracy():
    """D1: int8 KV cache keeps decode logits within ~1% of bf16 path."""
    from repro.configs.registry import reduced_config
    from repro.models.model import Model
    cfg = reduced_config("qwen1.5-32b", kv_cache_quant=True)
    cfg_ref = reduced_config("qwen1.5-32b")
    m, mr = Model(cfg), Model(cfg_ref)
    params = mr.init(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    full, _ = mr.forward(params, {"tokens": tokens, "labels": tokens})
    lg, cache = m.prefill(params, {"tokens": tokens[:, :12]}, max_len=20)
    assert cache["layers"]["k"].dtype == jnp.int8
    errs = [float(jnp.abs(lg[:, 0] - full[:, 11]).max())]
    for i in range(12, 16):
        lg, cache = m.decode_step(params, cache, tokens[:, i:i + 1])
        errs.append(float(jnp.abs(lg[:, 0] - full[:, i]).max()))
    rel = max(errs) / float(jnp.abs(full).max())
    assert rel < 0.05, (errs, rel)
