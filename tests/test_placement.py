"""Placement layer: constraint derivation, the hard anti-affinity cap,
ablation scoring, interference multipliers, packed-cluster arithmetic,
and the packed online serving plane built on top of it."""
import dataclasses
import json
import math

import pytest

from repro.core.campaign import PortfolioSpec, ReplaySpec
from repro.core.engine import ClusterModel
from repro.core.dag import Workflow
from repro.core.online import OnlineSpec, run_online
from repro.core.placement import (PlacementConstraints, PlacementSolution,
                                  PlacementSpec, TenantCell,
                                  derive_constraints, heavy_cap,
                                  interference_multipliers, pack_cells,
                                  plan_placement, round_robin_placement,
                                  scale_cluster, solve_placement)
from repro.serverless.function import FunctionSpec
from repro.serverless.generator import (chain_workflow, fan_workflow,
                                        load_shift_schedule)


def _fn(name, io=0.5, profile="", floor=256.0):
    return FunctionSpec(name=name, cpu_work=2.0, parallel_frac=0.5,
                        mem_floor=floor, mem_knee=2.0 * floor,
                        io_time=io, profile=profile)


def _gen_cells(n, size=6, n_bins=3, seed0=0):
    cells = []
    for i in range(n):
        mk = chain_workflow if i % 2 == 0 else fan_workflow
        wf = mk(size, seed=seed0 + i, tenant=f"t{i}")
        cells.append(TenantCell(template=wf, configs={}))
    return cells


# --------------------------------------------------------------------------
# constraints
# --------------------------------------------------------------------------

def test_placement_chatty_and_heavy_derivation():
    wf = Workflow("w", tenant="T")
    wf.add_function("a", payload=_fn("a", io=2.0))
    wf.add_function("b", payload=_fn("b", io=1.5))
    wf.add_function("c", payload=_fn("c", io=0.1))
    wf.add_function("h1", payload=_fn("h1", profile="mem_bound"))
    wf.add_function("h2", payload=_fn("h2", profile="", floor=4096.0))
    wf.add_function("nh", payload=_fn("nh", profile="cpu_bound",
                                      floor=4096.0))
    for src, dst in (("a", "b"), ("b", "c"), ("c", "h1"), ("h1", "h2"),
                     ("h2", "nh")):
        wf.add_edge(src, dst)
    cons = derive_constraints([TenantCell(template=wf, configs={})],
                              PlacementSpec(n_bins=2))
    # a->b combined io 3.5 >= 3.0 is chatty; b->c at 1.6 is not
    assert (("T", "a"), ("T", "b")) in cons.chatty
    assert (("T", "b"), ("T", "c")) not in cons.chatty
    # profile match and the working-set fallback are heavy; a *set*
    # profile that is not mem_bound is not, whatever its floor
    assert cons.heavy_set == {("T", "h1"), ("T", "h2")}


def test_placement_heavy_cap_formula():
    assert heavy_cap(0, 4) == 0
    assert heavy_cap(1, 4) == 1
    assert heavy_cap(4, 4) == 1
    assert heavy_cap(5, 4) == 2
    assert heavy_cap(9, 2) == 5


@pytest.mark.parametrize("seed", range(4))
def test_placement_anti_affinity_cap_never_violated(seed):
    """No accepted placement — greedy or any local-search move — may
    put more than ``ceil(n_heavy / n_bins)`` heavy functions in a bin."""
    spec = PlacementSpec(n_bins=3, seed=seed)
    cells = _gen_cells(5, size=7, seed0=10 * seed)
    cons = derive_constraints(cells, spec)
    sol = solve_placement(cells, spec)
    counts = sol.heavy_per_bin(cons)
    assert sum(counts) == len(cons.heavy)
    assert max(counts, default=0) <= heavy_cap(len(cons.heavy), 3)


def test_placement_duplicate_identity_rejected():
    a = TenantCell(template=chain_workflow(4, seed=1, tenant="same"),
                   configs={})
    b = TenantCell(template=fan_workflow(4, seed=2, tenant="same"),
                   configs={})
    with pytest.raises(ValueError, match="same"):
        pack_cells([a, b])
    with pytest.raises(ValueError, match="unique tenant"):
        plan_placement([a, b], PlacementSpec())


def test_placement_scale_cluster():
    c = scale_cluster(ClusterModel(total_cpu=10.0, total_mem_mb=1024.0), 4)
    assert c.total_cpu == 40.0 and c.total_mem_mb == 4096.0
    inf = scale_cluster(ClusterModel(), 4)
    assert math.isinf(inf.total_cpu) and math.isinf(inf.total_mem_mb)
    with pytest.raises(ValueError):
        scale_cluster(ClusterModel(), 0)


# --------------------------------------------------------------------------
# solver vs ablation
# --------------------------------------------------------------------------

def test_placement_affinity_scores_no_worse_than_round_robin():
    spec = PlacementSpec(n_bins=4)
    cluster = ClusterModel(total_cpu=200.0, total_mem_mb=200.0 * 1024.0)
    cells = _gen_cells(4, size=6)
    aff = solve_placement(cells, spec, cluster)
    rr = round_robin_placement(cells, spec, cluster)
    assert aff.method == "affinity" and rr.method == "round_robin"
    assert aff.score <= rr.score + 1e-12


def test_placement_plan_is_deterministic():
    spec = PlacementSpec(n_bins=3, seed=7)
    cells = _gen_cells(4)
    p1 = plan_placement(cells, spec)
    p2 = plan_placement(cells, spec)
    assert p1.solution.assignment == p2.solution.assignment
    assert p1.multipliers == p2.multipliers
    assert p1.solution.score == p2.solution.score


# --------------------------------------------------------------------------
# interference multipliers
# --------------------------------------------------------------------------

def test_placement_interference_multipliers():
    cons = PlacementConstraints(
        chatty=((("A", "p"), ("A", "c")),     # co-located below
                (("B", "p"), ("B", "c"))),    # split below
        heavy=(("A", "h1"), ("B", "h2")))
    sol = PlacementSolution(
        assignment={("A", "p"): 0, ("A", "c"): 0,
                    ("B", "p"): 0, ("B", "c"): 1,
                    ("A", "h1"): 2, ("B", "h2"): 2},
        n_bins=3, score=0.0, method="affinity")
    spec = PlacementSpec(n_bins=3)
    mult = interference_multipliers(sol, cons, spec)
    # co-located chatty pair: both endpoints speed up
    assert mult[("A", "p")] == pytest.approx(1.0 - spec.colocate_bonus)
    assert mult[("A", "c")] == pytest.approx(1.0 - spec.colocate_bonus)
    # split chatty edge: only the consumer pays the remote transfer
    assert mult[("B", "c")] == pytest.approx(1.0 + spec.remote_penalty)
    assert ("B", "p") not in mult
    # two co-resident heavies slow each other down
    expected = 1.0 + spec.interference_penalty
    assert mult[("A", "h1")] == pytest.approx(expected)
    assert mult[("B", "h2")] == pytest.approx(expected)


def test_placement_spec_validation():
    with pytest.raises(ValueError):
        PlacementSpec(n_bins=0)
    with pytest.raises(ValueError):
        PlacementSpec(remote_penalty=1.0)
    with pytest.raises(ValueError):
        PlacementSpec(colocate_bonus=-0.1)


# --------------------------------------------------------------------------
# the packed online serving plane
# --------------------------------------------------------------------------

SMALL = OnlineSpec(
    portfolio=PortfolioSpec(n_workflows=2, size=4, kinds=("chain",),
                            slo_slacks=(1.6,)),
    replay=ReplaySpec(n_instances=8, rate=0.2,
                      cluster=ClusterModel(total_cpu=80.0,
                                           total_mem_mb=80.0 * 1024.0)),
    n_epochs=3, drift=load_shift_schedule(1, 2.0), seed=0, mode="never")


def test_placement_packed_online_payload_is_deterministic():
    spec = dataclasses.replace(SMALL, placement=PlacementSpec(n_bins=2))
    r1, r2 = run_online(spec), run_online(spec)
    p1 = json.dumps(r1.to_payload(), sort_keys=True)
    p2 = json.dumps(r2.to_payload(), sort_keys=True)
    assert p1 == p2
    assert r1.placement["method"] == "affinity"
    assert r1.placement["cluster_cpu"] == pytest.approx(2 * 80.0)
    assert 0.0 <= r1.mean_attainment() <= 1.0
    assert len(r1.epochs) == 3 * len(r1.cells)


def test_placement_keys_absent_from_non_packed_payload():
    """``placement=None`` must leave the payload byte-compatible with
    pre-placement artifacts: no placement keys anywhere."""
    payload = run_online(SMALL).to_payload()
    assert "placement" not in payload
    assert "placement" not in payload["spec"]


def test_placement_packed_reconfiguration_loop_runs():
    """Challenger validation inside the packed cluster (the
    ``mode="every_epoch"`` path) completes and keeps per-tenant
    accounting sound."""
    spec = dataclasses.replace(SMALL, mode="every_epoch", n_epochs=2,
                               total_budget=64,
                               placement=PlacementSpec(n_bins=2))
    rep = run_online(spec)
    assert 0.0 <= rep.mean_attainment() <= 1.0
    assert rep.placement["n_bins"] == 2
    for row in rep.epochs:
        assert row["cost"] >= 0.0


def test_placement_bench_payload_strips_wall_clock():
    from benchmarks.placement import deterministic_payload
    row = {"case": "x", "packed_attainment": 1.0, "wall_s": 1.23}
    assert deterministic_payload(row) == {"case": "x",
                                          "packed_attainment": 1.0}
