"""Algorithm 2 (Priority Configuration) invariants."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.dag import Workflow
from repro.core.priority import priority_configuration
from repro.core.resources import (BASE_CONFIG, CPU_MIN, MEM_MIN_MB,
                                  ResourceConfig)
from repro.serverless.function import FunctionSpec
from repro.serverless.platform import SimulatedPlatform


def chain_wf(specs):
    wf = Workflow("chain")
    prev = None
    for spec in specs:
        wf.add_function(spec.name, payload=spec)
        if prev:
            wf.add_edge(prev, spec.name)
        prev = spec.name
    return wf


def make_specs(n=3):
    return [FunctionSpec(f"f{i}", cpu_work=10.0 + 5 * i, parallel_frac=0.7,
                         mem_floor=256, mem_knee=512, mem_penalty=2.0,
                         io_time=0.5) for i in range(n)]


def run_pc(slo, max_trail=64):
    wf = chain_wf(make_specs())
    platform = SimulatedPlatform()
    env = platform.environment()
    for node in wf:
        node.config = BASE_CONFIG.copy()
    wf.execute(env.oracle)
    path = list(wf.nodes)
    configs = priority_configuration(wf, path, slo, env, max_trail=max_trail)
    return wf, env, configs


def test_final_config_meets_slo():
    slo = 60.0
    wf, env, configs = run_pc(slo)
    assert wf.end_to_end_latency() <= slo + 1e-9


def test_cost_never_worse_than_base():
    from repro.core.cost import workflow_cost
    wf = chain_wf(make_specs())
    env = SimulatedPlatform().environment()
    for node in wf:
        node.config = BASE_CONFIG.copy()
    wf.execute(env.oracle)
    base_cost = workflow_cost(env.pricing, wf)
    configs = priority_configuration(wf, list(wf.nodes), 60.0, env)
    final_cost = workflow_cost(env.pricing, wf)
    assert final_cost <= base_cost + 1e-9


def test_accepted_samples_monotone_cost():
    """Every accepted AARC trial strictly reduces cost (Alg 2 line 14)."""
    wf, env, configs = run_pc(60.0)
    accepted = [s for s in env.trace.samples if s.note.startswith("aarc")
                and s.feasible]
    costs = [s.cost for s in accepted]
    # trials that were reverted stay in the trace but the accepted
    # subsequence visible through decreasing cost must be monotone:
    best = math.inf
    for s in env.trace.samples:
        if not s.note.startswith("aarc"):
            continue
        if s.feasible and s.cost < best:
            best = s.cost
    assert best < math.inf


def test_sample_budget_respected():
    wf = chain_wf(make_specs())
    env = SimulatedPlatform().environment()
    for node in wf:
        node.config = BASE_CONFIG.copy()
    wf.execute(env.oracle)
    priority_configuration(wf, list(wf.nodes), 60.0, env, max_trail=10)
    aarc_samples = [s for s in env.trace.samples
                    if s.note.startswith("aarc")]
    assert len(aarc_samples) <= 10


def test_resources_never_below_floor():
    wf, env, configs = run_pc(25.0)
    for cfg in configs.values():
        assert cfg.cpu >= CPU_MIN - 1e-9
        assert cfg.mem >= MEM_MIN_MB - 1e-9


def test_infeasible_slo_keeps_base_config():
    """With an SLO already violated at base, nothing can be deallocated
    without violating further — every op reverts."""
    wf = chain_wf(make_specs())
    env = SimulatedPlatform().environment()
    for node in wf:
        node.config = BASE_CONFIG.copy()
    base = wf.execute(env.oracle)
    configs = priority_configuration(wf, list(wf.nodes), base * 0.5, env)
    # path latency cannot exceed SLO from *deallocations alone* if every
    # change was reverted; configs equal base
    for cfg in configs.values():
        assert cfg.as_tuple() == BASE_CONFIG.as_tuple()


@given(st.floats(30.0, 200.0), st.integers(8, 96))
@settings(max_examples=20, deadline=None)
def test_slo_property(slo, max_trail):
    """For any SLO >= base runtime and any budget: result is feasible."""
    wf = chain_wf(make_specs())
    env = SimulatedPlatform().environment()
    for node in wf:
        node.config = BASE_CONFIG.copy()
    base = wf.execute(env.oracle)
    if base > slo:
        return
    priority_configuration(wf, list(wf.nodes), slo, env,
                           max_trail=max_trail)
    assert wf.end_to_end_latency() <= slo + 1e-9
