"""`FleetEngine.run_many` equivalence bar + SoA report memoization.

The batched replay plane (C candidate config-maps × S arrival seeds
over a shared topology) must be **bit-identical** to the looped scalar
path — ``run([template.copy() + configs, ...], times)`` per cell — on
every compared field, across topology families, finite and infinite
clusters, cold starts + keep-alive expiry, the carry/backlog path the
online challenger gate uses (input carries and ``collect_carry``
output), unbounded-failure candidates, mixed batches, and — under the
paired replay-stream contract — stochastic backends, where the
vectorized planes must match the exact event loop replaying the same
noise plan.
"""
import math

import numpy as np
import pytest

from repro.core.backend import CallableBackend
from repro.core.cost import PricingModel
from repro.core.engine import (ClusterModel, ColdStartModel, FleetCarry,
                               FleetEngine, PoissonArrivals)
from repro.core.resources import ResourceConfig
from repro.serverless.generator import (chain_workflow, diamond_workflow,
                                        fan_workflow, layered_workflow)
from repro.serverless.platform import (AnalyticBackend, SimulatedPlatform,
                                       StochasticBackend)

TOPOLOGIES = {
    "chain": lambda: chain_workflow(5, seed=11),
    "fan": lambda: fan_workflow(4, seed=12),
    "diamond": lambda: diamond_workflow(2, seed=13),
    "layered": lambda: layered_workflow(10, n_layers=3, seed=14),
}


def make_engine(**kw):
    env = SimulatedPlatform().environment()
    return FleetEngine(env.backend, pricing=env.pricing, **kw)


def candidate_sets(template, n_cand, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_cand):
        out.append({n.name: ResourceConfig(cpu=float(rng.uniform(1.0, 8.0)),
                                           mem=float(rng.uniform(1024.0,
                                                                 8192.0)))
                    for n in template})
    return out


def arrival_sets(n_seeds, n=6, rate=0.25, start=0.0):
    return [PoissonArrivals(rate, n, seed=s, start=start).times()
            for s in range(n_seeds)]


def scalar_cell(engine, template, configs, times, carry=None):
    wfs = []
    for _ in range(len(times)):
        wf = template.copy()
        wf.apply_configs(configs)
        wfs.append(wf)
    return engine.run(wfs, times, carry=carry)


def assert_reports_identical(got, want):
    """Every compared field exact — the acceptance-criteria bar."""
    assert np.array_equal(got.arrivals, want.arrivals)
    assert np.array_equal(got.finishes, want.finishes)
    assert np.array_equal(got.latencies, want.latencies)
    assert np.array_equal(got.queue_delays, want.queue_delays)
    assert np.array_equal(got.cold_delays, want.cold_delays)
    assert np.array_equal(got.costs, want.costs)
    assert np.array_equal(got.failed_mask, want.failed_mask)
    assert got.makespan == want.makespan
    assert got.queue_delay_by_function == want.queue_delay_by_function
    assert got.total_cost == want.total_cost
    assert got.total_queue_delay == want.total_queue_delay
    assert got.p50 == want.p50 and got.p99 == want.p99


def assert_grid_identical(engine, template, cands, seeds, carry=None):
    reports = engine.run_many(template, cands, seeds, carry=carry)
    assert len(reports) == len(cands) * len(seeds)
    k = 0
    for configs in cands:                    # candidate-major ordering
        for times in seeds:
            assert_reports_identical(
                reports[k], scalar_cell(engine, template, configs, times,
                                        carry=carry))
            k += 1
    return reports


@pytest.mark.parametrize("kind", list(TOPOLOGIES))
def test_run_many_bit_identical_infinite_cluster(kind):
    """Vectorized plane == looped scalar run on every topology family."""
    template = TOPOLOGIES[kind]()
    engine = make_engine()
    assert_grid_identical(engine, template,
                          candidate_sets(template, 3, seed=1),
                          arrival_sets(2))


@pytest.mark.parametrize("kind", list(TOPOLOGIES))
def test_run_many_bit_identical_finite_cluster(kind):
    """Finite capacity routes onto the table-driven constrained plane,
    which must reproduce the looped run bit-for-bit (queuing
    included)."""
    template = TOPOLOGIES[kind]()
    engine = make_engine(cluster=ClusterModel(total_cpu=12.0,
                                              total_mem_mb=16384.0))
    cands = [{n.name: ResourceConfig(cpu=4.0, mem=4096.0) for n in template},
             {n.name: ResourceConfig(cpu=6.0, mem=6144.0) for n in template}]
    reports = assert_grid_identical(engine, template, cands,
                                    arrival_sets(2, rate=2.0))
    assert any(r.total_queue_delay > 0.0 for r in reports)


def test_run_many_bit_identical_with_cold_starts():
    template = TOPOLOGIES["chain"]()
    engine = make_engine(cold_start=ColdStartModel(delay_s=1.5,
                                                   keep_alive_s=60.0))
    reports = assert_grid_identical(engine, template,
                                    candidate_sets(template, 2, seed=2),
                                    arrival_sets(2))
    assert all(r.cold_delays.sum() > 0.0 for r in reports)


def test_run_many_bit_identical_from_live_backlog():
    """The online-challenger path: replay from a carried fleet state
    (warm pool + in-flight reservations of a previous epoch)."""
    template = TOPOLOGIES["layered"]()
    # epoch 0 on a tight cluster leaves work in flight at the boundary
    engine = make_engine(cluster=ClusterModel(total_cpu=14.0,
                                              total_mem_mb=20480.0),
                         cold_start=ColdStartModel(delay_s=0.5,
                                                   keep_alive_s=500.0))
    first = engine.run(
        [template.copy() for _ in range(6)],
        PoissonArrivals(1.0, 6, seed=7).times(), collect_carry=True)
    boundary = 30.0
    carry = first.carry.pruned(boundary)
    assert carry.warm                       # the backlog is real
    cands = candidate_sets(template, 2, seed=3)
    seeds = [PoissonArrivals(1.0, 6, seed=8, start=boundary).times()]
    assert_grid_identical(engine, template, cands, seeds, carry=carry)


def test_run_many_busy_carry_on_infinite_cluster_stays_exact():
    """An inert busy reservation still extends the measured makespan;
    the vectorized plane must reproduce it."""
    template = TOPOLOGIES["chain"]()
    engine = make_engine()
    carry = FleetCarry(clock=0.0, warm={},
                       busy=[(900.0, 2.0, 512.0), (0.1, 1.0, 128.0)])
    reports = assert_grid_identical(engine, template,
                                    candidate_sets(template, 2, seed=4),
                                    arrival_sets(1), carry=carry)
    assert all(r.makespan > 800.0 for r in reports)


def test_run_many_empty_candidate_and_seed_sets():
    template = TOPOLOGIES["chain"]()
    engine = make_engine()
    assert engine.run_many(template, [], arrival_sets(2)) == []
    assert engine.run_many(template, candidate_sets(template, 2), []) == []
    # an empty arrival process yields the well-defined empty report
    reports = engine.run_many(template, candidate_sets(template, 2),
                              [np.empty(0)])
    assert len(reports) == 2
    for rep in reports:
        assert len(rep) == 0 and rep.instances == []
        assert rep.p99 == 0.0 and rep.slo_attainment(1.0) == 1.0


def test_run_many_unknown_function_name_raises_keyerror():
    template = TOPOLOGIES["chain"]()
    engine = make_engine()
    bad = {"no-such-function": ResourceConfig()}
    with pytest.raises(KeyError):
        engine.run_many(template, [bad], arrival_sets(1))


def test_run_many_uses_the_vectorized_plane():
    """On an infinite cluster with a deterministic surface the C×S grid
    must be ONE invoke_config_batch call — zero invoke_batch rounds."""
    template = TOPOLOGIES["fan"]()
    env = SimulatedPlatform().environment()
    calls = {"config_batch": 0, "batch": 0}
    real_cfg = env.backend.invoke_config_batch
    env.backend.invoke_config_batch = \
        lambda *a, **k: (calls.__setitem__("config_batch",
                                           calls["config_batch"] + 1)
                         or real_cfg(*a, **k))
    env.backend.invoke_batch = \
        lambda *a, **k: pytest.fail("scalar invoke_batch on the "
                                    "vectorized plane")
    engine = FleetEngine(env.backend, pricing=env.pricing)
    reports = engine.run_many(template, candidate_sets(template, 4, seed=5),
                              arrival_sets(3))
    assert calls["config_batch"] == 1
    assert len(reports) == 12


# -- stochastic paired replay-stream contract --------------------------

class _ScalarMirrorPricing(PricingModel):
    """Overrides scalar ``function_cost`` with the *same* values but no
    matching ``cost_batch``: routes replays onto the planned plane (the
    exact per-instance event loop driven off the precomputed runtime
    plan) without changing any number."""

    def function_cost(self, runtime_s, config):
        return super().function_cost(runtime_s, config)


def _stochastic_engine(seed, *, sigma=0.05, pricing=None, **kw):
    return FleetEngine(StochasticBackend(noise_sigma=sigma, seed=seed),
                       pricing=pricing or SimulatedPlatform().pricing, **kw)


CONSTRAINED_KW = dict(cluster=ClusterModel(total_cpu=12.0,
                                           total_mem_mb=16384.0),
                      cold_start=ColdStartModel(delay_s=1.0,
                                                keep_alive_s=30.0))


@pytest.mark.parametrize("engine_kw", [{}, CONSTRAINED_KW],
                         ids=["fast_plane", "constrained_plane"])
def test_run_many_stochastic_same_config_scores_identically(engine_kw):
    """The paired replay-stream contract: one (instance, function)
    noise tensor per plane, shared across candidates — so the same
    configuration in two candidate slots is the same experiment and
    must score bit-identically (a per-candidate stream would break
    the challenger gate's paired comparison)."""
    template = TOPOLOGIES["layered"]()
    cfg = candidate_sets(template, 1, seed=6)[0]
    reports = _stochastic_engine(123, **engine_kw).run_many(
        template, [cfg, cfg], arrival_sets(2))
    assert_reports_identical(reports[0], reports[2])
    assert_reports_identical(reports[1], reports[3])


@pytest.mark.parametrize("engine_kw", [{}, CONSTRAINED_KW],
                         ids=["fast_plane", "constrained_plane"])
def test_run_many_stochastic_matches_planned_event_loop(engine_kw):
    """Cross-plane bit-identity under noise: the vectorized planes must
    reproduce the exact per-instance event loop replaying the same
    plan. ``_ScalarMirrorPricing`` computes identical costs but forces
    the planned (event-loop) plane; both engines draw the identical
    noise tensor (same backend seed, ONE replay_noise advance per
    plane), so every compared field must agree bit-for-bit."""
    template = TOPOLOGIES["layered"]()
    cands = candidate_sets(template, 3, seed=7)
    seeds = arrival_sets(2)
    vec = _stochastic_engine(99, **engine_kw).run_many(
        template, cands, seeds)
    ref = _stochastic_engine(99, pricing=_ScalarMirrorPricing(),
                             **engine_kw).run_many(template, cands, seeds)
    for got, want in zip(vec, ref):
        assert_reports_identical(got, want)


def test_run_many_stochastic_replay_is_reproducible_and_noisy():
    template = TOPOLOGIES["chain"]()
    cands = candidate_sets(template, 2, seed=8)
    seeds = arrival_sets(2)
    a = _stochastic_engine(7).run_many(template, cands, seeds)
    b = _stochastic_engine(7).run_many(template, cands, seeds)
    for ra, rb in zip(a, b):                 # same seed => same plane
        assert_reports_identical(ra, rb)
    exact = make_engine().run_many(template, cands, seeds)
    assert any(not np.array_equal(ra.finishes, re.finishes)
               for ra, re in zip(a, exact))  # noise is actually applied
    # sigma=0 declares an exact surface: bitwise the analytic plane
    silent = _stochastic_engine(7, sigma=0.0).run_many(
        template, cands, seeds)
    for rs, re in zip(silent, exact):
        assert_reports_identical(rs, re)


def test_run_many_stochastic_consumes_one_noise_draw_per_plane():
    """The plane must advance the backend's RNG exactly once
    (replay_noise), never per cell/candidate — that is what makes
    batched replays paired AND reproducible."""
    template = TOPOLOGIES["fan"]()
    backend = StochasticBackend(noise_sigma=0.05, seed=11)
    draws = {"n": 0}
    real = backend.replay_noise

    def counting(n_instances, n_nodes):
        draws["n"] += 1
        return real(n_instances, n_nodes)

    backend.replay_noise = counting
    backend.invoke_batch = lambda *a, **k: pytest.fail(
        "per-cell invoke_batch on the batched replay plane")
    engine = FleetEngine(backend, pricing=SimulatedPlatform().pricing,
                         **CONSTRAINED_KW)
    reports = engine.run_many(template, candidate_sets(template, 3, seed=9),
                              arrival_sets(2))
    assert draws["n"] == 1
    assert len(reports) == 6


class _NoClampBackend(AnalyticBackend):
    """Deterministic surface whose failures are unbounded (+inf): a
    dead instance never runs its downstream nodes, which the fast
    plane's longest-path sweep cannot see — those candidates replay
    per-cell off the precomputed plan (the constrained plane handles
    them natively)."""

    has_clamped = False

    def _surface(self, cpu, mem, spec_arrays):
        rt, failed = super()._surface(cpu, mem, spec_arrays)
        return np.where(failed, np.inf, rt), failed


def test_run_many_serializes_unbounded_failure_candidates():
    template = TOPOLOGIES["fan"]()
    healthy = {n.name: ResourceConfig(cpu=4.0, mem=8192.0)
               for n in template}
    dying = {n.name: ResourceConfig(cpu=4.0, mem=128.0)    # below floors
             for n in template}
    engine = FleetEngine(_NoClampBackend(),
                         pricing=SimulatedPlatform().pricing)
    reports = assert_grid_identical(engine, template, [healthy, dying],
                                    arrival_sets(2))
    assert not reports[0].failed_mask.any()
    assert reports[2].failed_mask.all()
    assert math.isinf(reports[2].p99)


def test_run_many_mixed_unbounded_failures_on_finite_cluster():
    """The production-shaped mixed batch: finite CPU+mem cluster, cold
    starts, one healthy and one unbounded-failure candidate — the
    constrained plane replays dead instances natively (slot release +
    same-instant re-admission round) and must stay bit-identical."""
    template = TOPOLOGIES["fan"]()
    healthy = {n.name: ResourceConfig(cpu=4.0, mem=8192.0)
               for n in template}
    dying = {n.name: ResourceConfig(cpu=4.0, mem=128.0)
             for n in template}
    engine = FleetEngine(_NoClampBackend(),
                         pricing=SimulatedPlatform().pricing,
                         cluster=ClusterModel(total_cpu=10.0,
                                              total_mem_mb=20480.0),
                         cold_start=ColdStartModel(delay_s=0.5,
                                                   keep_alive_s=20.0))
    reports = assert_grid_identical(engine, template, [healthy, dying],
                                    arrival_sets(2, rate=2.0))
    assert not reports[0].failed_mask.any()
    assert reports[2].failed_mask.all()


def test_opaque_callable_backend_falls_back_and_matches():
    """Backends without a config-batch surface (bare oracles) keep the
    exact looped semantics."""
    template = TOPOLOGIES["chain"]()
    engine = FleetEngine(CallableBackend(lambda node: node.config.cpu * 0.1),
                         pricing=SimulatedPlatform().pricing)
    assert_grid_identical(engine, template,
                          candidate_sets(template, 2, seed=8),
                          arrival_sets(2))


def test_run_many_single_instance_cell_matches_degenerate_path():
    """A fleet of one goes through ``run``'s degenerate fast path,
    whose float associations differ from the absolute-time plane —
    run_many replays that cell off the precomputed plan (through the
    same degenerate path) to stay bit-identical. Uses a
    template whose insertion order differs from topological order so
    any accumulation-order divergence would surface."""
    from repro.core.dag import Workflow
    from repro.serverless.generator import random_spec

    rng = np.random.default_rng(5)
    template = Workflow("scrambled")
    for name in ("f2", "f0", "f1"):          # non-topological insertion
        template.add_function(name, payload=random_spec(name, rng))
    template.add_edge("f0", "f1")
    template.add_edge("f1", "f2")
    engine = make_engine()
    cands = candidate_sets(template, 2, seed=10)
    # nonzero arrival: the degenerate path computes e2e relative and
    # shifts by the arrival, unlike the absolute event-time chain
    assert_grid_identical(engine, template, cands,
                          [np.array([13.7])])


def test_custom_pricing_overrides_are_honored():
    """A pricing model that customizes only scalar function_cost must
    not be silently priced with the base mu-formula (neither by the
    admission rounds nor by the run_many plane)."""
    from repro.core.cost import PricingModel

    class DoubledPricing(PricingModel):
        def function_cost(self, runtime_s, config):
            return 2.0 * super().function_cost(runtime_s, config)

    template = TOPOLOGIES["chain"]()
    env = SimulatedPlatform().environment()
    base = FleetEngine(env.backend)
    doubled = FleetEngine(env.backend, pricing=DoubledPricing())
    assert not doubled._pricing_vectorized     # falls back to scalar
    cands = candidate_sets(template, 1, seed=11)
    times = arrival_sets(1)[0]
    got = doubled.run_many(template, cands, [times])[0]
    ref = base.run_many(template, cands, [times])[0]
    assert got.total_cost == pytest.approx(2.0 * ref.total_cost)
    # a custom *vectorized* implementation is trusted as-is
    class VectorizedDoubled(DoubledPricing):
        def cost_batch(self, runtime_s, cpu, mem):
            return 2.0 * super().cost_batch(runtime_s, cpu, mem)

    vec = FleetEngine(env.backend, pricing=VectorizedDoubled())
    assert vec._pricing_vectorized
    got_vec = vec.run_many(template, cands, [times])[0]
    assert got_vec.total_cost == pytest.approx(got.total_cost)


def test_online_stochastic_validation_stays_paired():
    """On a stochastic backend the challenger gate must remain a
    *paired* comparison: every candidate validated under identical
    noise draws. The same configuration in both slots must therefore
    score identically (a shared noise stream would break this)."""
    from repro.core.campaign import PortfolioSpec, ReplaySpec
    from repro.core.online import OnlineController, OnlineSpec
    from repro.serverless.generator import EpochConditions
    from repro.serverless.platform import make_env

    spec = OnlineSpec(
        portfolio=PortfolioSpec(n_workflows=1, size=4, slo_slacks=(2.0,)),
        replay=ReplaySpec(n_instances=6, rate=0.5), n_epochs=1)
    ctl = OnlineController(
        spec, env_factory=lambda: make_env(noise_sigma=0.05, seed=17))
    tasks = ctl._campaign.tasks()
    cells = ctl._deploy(tasks, ctl._campaign.arrival_seeds(len(tasks)))
    cond = EpochConditions()
    cfg = cells[0].configs
    a, b = ctl._validate_many(cells[0], [cfg, cfg], cond, seed=3)
    assert a == b


def test_run_many_cold_start_keep_alive_expiry_bit_identical():
    """Warm containers must expire mid-replay: a keep-alive shorter
    than the arrival gaps makes later instances pay the cold delay
    again, and the table-driven plane must mirror the scalar pool
    bookkeeping exactly."""
    template = TOPOLOGIES["chain"]()
    engine = make_engine(cold_start=ColdStartModel(delay_s=2.0,
                                                   keep_alive_s=0.75))
    reports = assert_grid_identical(engine, template,
                                    candidate_sets(template, 2, seed=12),
                                    arrival_sets(2, rate=0.05))
    # sparse arrivals + fast expiry: every instance provisions cold
    assert all((r.cold_delays >= 2.0).all() for r in reports)


def test_run_many_collect_carry_matches_scalar():
    """``collect_carry=True`` routes onto the constrained plane; each
    cell's report AND emitted carry (clock, warm pool, reservation log)
    must equal the scalar run's exactly."""
    template = TOPOLOGIES["layered"]()
    engine = make_engine(cluster=ClusterModel(total_cpu=14.0,
                                              total_mem_mb=20480.0),
                         cold_start=ColdStartModel(delay_s=0.5,
                                                   keep_alive_s=120.0))
    cands = candidate_sets(template, 2, seed=13)
    seeds = arrival_sets(2, rate=1.0)
    reports = engine.run_many(template, cands, seeds, collect_carry=True)
    k = 0
    for configs in cands:
        for times in seeds:
            wfs = []
            for _ in range(len(times)):
                wf = template.copy()
                wf.apply_configs(configs)
                wfs.append(wf)
            want = engine.run(wfs, times, collect_carry=True)
            assert_reports_identical(reports[k], want)
            assert reports[k].carry == want.carry
            assert reports[k].carry.busy       # the backlog is real
            k += 1


def test_run_many_one_surface_one_pricing_call_on_constrained_plane():
    """The constrained plane's whole C×S grid must cost ONE
    ``invoke_config_batch`` and ONE ``cost_batch`` — the per-cell event
    loops run off the precomputed tables with zero backend/pricing
    dispatch."""
    calls = {"cost": 0}

    class CountingPricing(PricingModel):
        def cost_batch(self, runtime_s, cpu, mem):
            calls["cost"] += 1
            return super().cost_batch(runtime_s, cpu, mem)

    template = TOPOLOGIES["layered"]()
    env = SimulatedPlatform().environment()
    surface = {"n": 0}
    real_cfg = env.backend.invoke_config_batch
    env.backend.invoke_config_batch = \
        lambda *a, **k: (surface.__setitem__("n", surface["n"] + 1)
                         or real_cfg(*a, **k))
    env.backend.invoke_batch = \
        lambda *a, **k: pytest.fail("scalar invoke_batch on the "
                                    "constrained plane")
    engine = FleetEngine(env.backend, pricing=CountingPricing(),
                         cluster=ClusterModel(total_cpu=14.0,
                                              total_mem_mb=20480.0),
                         cold_start=ColdStartModel(delay_s=0.5,
                                                   keep_alive_s=60.0))
    reports = engine.run_many(template, candidate_sets(template, 4, seed=14),
                              arrival_sets(3, rate=1.0))
    assert surface["n"] == 1
    assert calls["cost"] == 1
    assert len(reports) == 12
    assert any(r.total_queue_delay > 0.0 for r in reports)


# -- batch_eligibility diagnostic --------------------------------------

def test_batch_eligibility_reports_plane_routing():
    template = TOPOLOGIES["chain"]()

    fast = make_engine().batch_eligibility(template, [])
    assert fast == {"plane": "fast", "vectorized": True, "reasons": [],
                    "serial_candidates": None}

    constrained = make_engine(**CONSTRAINED_KW).batch_eligibility(
        template, [])
    assert constrained["plane"] == "constrained"
    assert constrained["vectorized"]
    joined = " ".join(constrained["reasons"])
    assert "finite cluster" in joined and "cold starts" in joined

    carry_plane = make_engine().batch_eligibility(template, [],
                                                  collect_carry=True)
    assert carry_plane["plane"] == "constrained"
    assert any("collect_carry" in r for r in carry_plane["reasons"])

    env = SimulatedPlatform().environment()
    planned = FleetEngine(env.backend,
                          pricing=_ScalarMirrorPricing()).batch_eligibility(
        template, [])
    assert planned["plane"] == "planned"
    assert not planned["vectorized"]
    assert any("pricing" in r for r in planned["reasons"])

    opaque = FleetEngine(CallableBackend(lambda node: 0.1),
                         pricing=env.pricing).batch_eligibility(template, [])
    assert opaque["plane"] == "serial"
    assert not opaque["vectorized"]
    assert any("batch_safe" in r for r in opaque["reasons"])

    from repro.core.dag import Workflow
    empty = make_engine().batch_eligibility(Workflow("empty"), [])
    assert empty["plane"] == "serial"
    assert any("empty template" in r for r in empty["reasons"])

    # a batch_safe stochastic backend rides the plane
    stoch = _stochastic_engine(0, **CONSTRAINED_KW).batch_eligibility(
        template, [])
    assert stoch["plane"] == "constrained" and stoch["vectorized"]


def test_batch_eligibility_probes_unbounded_failure_candidates():
    template = TOPOLOGIES["fan"]()
    healthy = {n.name: ResourceConfig(cpu=4.0, mem=8192.0)
               for n in template}
    dying = {n.name: ResourceConfig(cpu=4.0, mem=128.0)
             for n in template}
    engine = FleetEngine(_NoClampBackend(),
                         pricing=SimulatedPlatform().pricing)
    elig = engine.batch_eligibility(template, [healthy, dying],
                                    probe_candidates=True)
    assert elig["plane"] == "fast"
    assert elig["serial_candidates"] == [1]
    assert any("unbounded" in r for r in elig["reasons"])
    # without probing, no backend call is made and no verdict is given
    assert engine.batch_eligibility(template, [healthy, dying])[
        "serial_candidates"] is None


def test_campaign_logs_batched_replay_fallback(caplog):
    """Silent serialization must be visible: Campaign.replay_configs_many
    logs the eligibility verdict once per distinct cause."""
    import logging

    from repro.core.campaign import Campaign
    from repro.core.env import Environment

    campaign = Campaign()
    task = campaign.tasks()[0]
    configs = {name: ResourceConfig() for name in task.template.nodes}
    env = Environment(CallableBackend(lambda node: 0.1))
    with caplog.at_level(logging.INFO, logger="repro.core.campaign"):
        campaign.replay_configs_many(task, [configs], 3, env=env,
                                     n_instances=2)
        campaign.replay_configs_many(task, [configs], 4, env=env,
                                     n_instances=2)
    hits = [r for r in caplog.records if "serial plane" in r.message]
    assert len(hits) == 1                     # logged once per cause
    assert "batch_safe" in hits[0].message
    caplog.clear()
    with caplog.at_level(logging.INFO, logger="repro.core.campaign"):
        campaign.replay_configs_many(task, [configs], 5, n_instances=2)
    assert not [r for r in caplog.records if "plane" in r.message]


# -- pricing re-detection (per-pricing-object cache) -------------------

def test_pricing_vectorization_redetects_after_swap_and_mutation():
    env = SimulatedPlatform().environment()
    engine = FleetEngine(env.backend)
    assert engine._pricing_vectorized

    class Mutant(PricingModel):
        pass

    # swapping the pricing object on a cached engine re-detects
    engine.pricing = Mutant()
    assert engine._pricing_vectorized          # nothing overridden yet
    # mutating the *class* after the verdict was cached re-detects too
    Mutant.function_cost = lambda self, runtime_s, config: 0.0
    assert not engine._pricing_vectorized
    del Mutant.function_cost
    assert engine._pricing_vectorized

    # and the verdict is honored end to end: the zero-cost mutant
    # prices every replay at exactly zero via the planned plane
    Mutant.function_cost = lambda self, runtime_s, config: 0.0
    template = TOPOLOGIES["chain"]()
    report = engine.run_many(template, candidate_sets(template, 1, seed=15),
                             arrival_sets(1))[0]
    assert report.total_cost == 0.0


# -- the jitted lax.scan fleet step ------------------------------------

def test_jax_plane_backend_matches_numpy_bitwise():
    pytest.importorskip("jax")
    template = TOPOLOGIES["layered"]()
    cands = candidate_sets(template, 3, seed=16)
    seeds = arrival_sets(2)
    carry = FleetCarry(clock=0.0, warm={}, busy=[(700.0, 2.0, 512.0)])
    numpy_reports = make_engine().run_many(template, cands, seeds,
                                           carry=carry)
    jax_reports = make_engine(plane_backend="jax").run_many(
        template, cands, seeds, carry=carry)
    for got, want in zip(jax_reports, numpy_reports):
        assert_reports_identical(got, want)


def test_unknown_plane_backend_rejected():
    env = SimulatedPlatform().environment()
    with pytest.raises(ValueError, match="plane_backend"):
        FleetEngine(env.backend, plane_backend="tpu")


# -- SoA report memoization (accessor-waste satellite) -----------------

def test_report_accessors_are_memoized():
    template = TOPOLOGIES["chain"]()
    engine = make_engine(cluster=ClusterModel(total_cpu=12.0,
                                              total_mem_mb=16384.0))
    rep = scalar_cell(engine, template,
                      candidate_sets(template, 1, seed=9)[0],
                      PoissonArrivals(1.0, 8, seed=1).times())
    assert rep.latencies is rep.latencies            # no rebuild per call
    assert rep.instances is rep.instances
    assert rep.total_cost == rep.total_cost
    assert rep.total_cost == sum(r.cost for r in rep.instances)
    assert rep.total_queue_delay == \
        sum(r.queue_delay for r in rep.instances)
    assert rep.slo_attainment(5.0) == rep.slo_attainment(5.0)
    # object view agrees with the arrays
    for i, r in enumerate(rep.instances):
        assert r.uid == i
        assert r.e2e == rep.latencies[i]
        assert r.cost == rep.costs[i]
        assert r.failed == rep.failed_mask[i]


def test_report_legacy_instances_constructor_roundtrips():
    from repro.core.engine import FleetReport, InstanceResult

    rows = [InstanceResult(uid=0, arrival=0.0, finish=2.0, e2e=2.0,
                           queue_delay=0.5, cold_delay=0.0, cost=1.25,
                           failed=False),
            InstanceResult(uid=1, arrival=1.0, finish=math.inf, e2e=math.inf,
                           queue_delay=0.0, cold_delay=0.0, cost=0.0,
                           failed=True)]
    rep = FleetReport(instances=rows, makespan=2.0,
                      cpu_utilization=0.0, mem_utilization=0.0,
                      queue_delay_by_function={})
    assert rep.instances == rows
    assert np.array_equal(rep.latencies, [2.0, math.inf])
    assert rep.slo_attainment(3.0) == 0.5
    assert rep.total_cost == 1.25
    assert rep.p50 == math.inf or rep.p50 == 2.0   # interpolation defined
    assert not math.isnan(rep.p99)
