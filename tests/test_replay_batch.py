"""`FleetEngine.run_many` equivalence bar + SoA report memoization.

The batched replay plane (C candidate config-maps × S arrival seeds
over a shared topology) must be **bit-identical** to the looped scalar
path — ``run([template.copy() + configs, ...], times)`` per cell — on
every compared field, across topology families, finite and infinite
clusters, cold starts, the carry/backlog path the online challenger
gate uses, and the serialized unbounded-failure case.
"""
import math

import numpy as np
import pytest

from repro.core.backend import CallableBackend
from repro.core.engine import (ClusterModel, ColdStartModel, FleetCarry,
                               FleetEngine, PoissonArrivals)
from repro.core.resources import ResourceConfig
from repro.serverless.generator import (chain_workflow, diamond_workflow,
                                        fan_workflow, layered_workflow)
from repro.serverless.platform import (AnalyticBackend, SimulatedPlatform,
                                       StochasticBackend)

TOPOLOGIES = {
    "chain": lambda: chain_workflow(5, seed=11),
    "fan": lambda: fan_workflow(4, seed=12),
    "diamond": lambda: diamond_workflow(2, seed=13),
    "layered": lambda: layered_workflow(10, n_layers=3, seed=14),
}


def make_engine(**kw):
    env = SimulatedPlatform().environment()
    return FleetEngine(env.backend, pricing=env.pricing, **kw)


def candidate_sets(template, n_cand, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_cand):
        out.append({n.name: ResourceConfig(cpu=float(rng.uniform(1.0, 8.0)),
                                           mem=float(rng.uniform(1024.0,
                                                                 8192.0)))
                    for n in template})
    return out


def arrival_sets(n_seeds, n=6, rate=0.25, start=0.0):
    return [PoissonArrivals(rate, n, seed=s, start=start).times()
            for s in range(n_seeds)]


def scalar_cell(engine, template, configs, times, carry=None):
    wfs = []
    for _ in range(len(times)):
        wf = template.copy()
        wf.apply_configs(configs)
        wfs.append(wf)
    return engine.run(wfs, times, carry=carry)


def assert_reports_identical(got, want):
    """Every compared field exact — the acceptance-criteria bar."""
    assert np.array_equal(got.arrivals, want.arrivals)
    assert np.array_equal(got.finishes, want.finishes)
    assert np.array_equal(got.latencies, want.latencies)
    assert np.array_equal(got.queue_delays, want.queue_delays)
    assert np.array_equal(got.cold_delays, want.cold_delays)
    assert np.array_equal(got.costs, want.costs)
    assert np.array_equal(got.failed_mask, want.failed_mask)
    assert got.makespan == want.makespan
    assert got.queue_delay_by_function == want.queue_delay_by_function
    assert got.total_cost == want.total_cost
    assert got.total_queue_delay == want.total_queue_delay
    assert got.p50 == want.p50 and got.p99 == want.p99


def assert_grid_identical(engine, template, cands, seeds, carry=None):
    reports = engine.run_many(template, cands, seeds, carry=carry)
    assert len(reports) == len(cands) * len(seeds)
    k = 0
    for configs in cands:                    # candidate-major ordering
        for times in seeds:
            assert_reports_identical(
                reports[k], scalar_cell(engine, template, configs, times,
                                        carry=carry))
            k += 1
    return reports


@pytest.mark.parametrize("kind", list(TOPOLOGIES))
def test_run_many_bit_identical_infinite_cluster(kind):
    """Vectorized plane == looped scalar run on every topology family."""
    template = TOPOLOGIES[kind]()
    engine = make_engine()
    assert_grid_identical(engine, template,
                          candidate_sets(template, 3, seed=1),
                          arrival_sets(2))


@pytest.mark.parametrize("kind", list(TOPOLOGIES))
def test_run_many_bit_identical_finite_cluster(kind):
    """Finite capacity genuinely serializes; the exact fallback must
    still reproduce the looped run bit-for-bit (queuing included)."""
    template = TOPOLOGIES[kind]()
    engine = make_engine(cluster=ClusterModel(total_cpu=12.0,
                                              total_mem_mb=16384.0))
    cands = [{n.name: ResourceConfig(cpu=4.0, mem=4096.0) for n in template},
             {n.name: ResourceConfig(cpu=6.0, mem=6144.0) for n in template}]
    reports = assert_grid_identical(engine, template, cands,
                                    arrival_sets(2, rate=2.0))
    assert any(r.total_queue_delay > 0.0 for r in reports)


def test_run_many_bit_identical_with_cold_starts():
    template = TOPOLOGIES["chain"]()
    engine = make_engine(cold_start=ColdStartModel(delay_s=1.5,
                                                   keep_alive_s=60.0))
    reports = assert_grid_identical(engine, template,
                                    candidate_sets(template, 2, seed=2),
                                    arrival_sets(2))
    assert all(r.cold_delays.sum() > 0.0 for r in reports)


def test_run_many_bit_identical_from_live_backlog():
    """The online-challenger path: replay from a carried fleet state
    (warm pool + in-flight reservations of a previous epoch)."""
    template = TOPOLOGIES["layered"]()
    # epoch 0 on a tight cluster leaves work in flight at the boundary
    engine = make_engine(cluster=ClusterModel(total_cpu=14.0,
                                              total_mem_mb=20480.0),
                         cold_start=ColdStartModel(delay_s=0.5,
                                                   keep_alive_s=500.0))
    first = engine.run(
        [template.copy() for _ in range(6)],
        PoissonArrivals(1.0, 6, seed=7).times(), collect_carry=True)
    boundary = 30.0
    carry = first.carry.pruned(boundary)
    assert carry.warm                       # the backlog is real
    cands = candidate_sets(template, 2, seed=3)
    seeds = [PoissonArrivals(1.0, 6, seed=8, start=boundary).times()]
    assert_grid_identical(engine, template, cands, seeds, carry=carry)


def test_run_many_busy_carry_on_infinite_cluster_stays_exact():
    """An inert busy reservation still extends the measured makespan;
    the vectorized plane must reproduce it."""
    template = TOPOLOGIES["chain"]()
    engine = make_engine()
    carry = FleetCarry(clock=0.0, warm={},
                       busy=[(900.0, 2.0, 512.0), (0.1, 1.0, 128.0)])
    reports = assert_grid_identical(engine, template,
                                    candidate_sets(template, 2, seed=4),
                                    arrival_sets(1), carry=carry)
    assert all(r.makespan > 800.0 for r in reports)


def test_run_many_empty_candidate_and_seed_sets():
    template = TOPOLOGIES["chain"]()
    engine = make_engine()
    assert engine.run_many(template, [], arrival_sets(2)) == []
    assert engine.run_many(template, candidate_sets(template, 2), []) == []
    # an empty arrival process yields the well-defined empty report
    reports = engine.run_many(template, candidate_sets(template, 2),
                              [np.empty(0)])
    assert len(reports) == 2
    for rep in reports:
        assert len(rep) == 0 and rep.instances == []
        assert rep.p99 == 0.0 and rep.slo_attainment(1.0) == 1.0


def test_run_many_unknown_function_name_raises_keyerror():
    template = TOPOLOGIES["chain"]()
    engine = make_engine()
    bad = {"no-such-function": ResourceConfig()}
    with pytest.raises(KeyError):
        engine.run_many(template, [bad], arrival_sets(1))


def test_run_many_uses_the_vectorized_plane():
    """On an infinite cluster with a deterministic surface the C×S grid
    must be ONE invoke_config_batch call — zero invoke_batch rounds."""
    template = TOPOLOGIES["fan"]()
    env = SimulatedPlatform().environment()
    calls = {"config_batch": 0, "batch": 0}
    real_cfg = env.backend.invoke_config_batch
    env.backend.invoke_config_batch = \
        lambda *a, **k: (calls.__setitem__("config_batch",
                                           calls["config_batch"] + 1)
                         or real_cfg(*a, **k))
    env.backend.invoke_batch = \
        lambda *a, **k: pytest.fail("scalar invoke_batch on the "
                                    "vectorized plane")
    engine = FleetEngine(env.backend, pricing=env.pricing)
    reports = engine.run_many(template, candidate_sets(template, 4, seed=5),
                              arrival_sets(3))
    assert calls["config_batch"] == 1
    assert len(reports) == 12


def test_run_many_stochastic_backend_takes_exact_serial_fallback():
    """A stateful backend must not be vectorized (draw order changes
    results); the fallback consumes the noise stream exactly like the
    hand-written loop."""
    template = TOPOLOGIES["chain"]()
    cands = candidate_sets(template, 2, seed=6)
    seeds = arrival_sets(2)

    def engine(seed):
        return FleetEngine(StochasticBackend(noise_sigma=0.05, seed=seed),
                           pricing=SimulatedPlatform().pricing)

    got = engine(123).run_many(template, cands, seeds)
    ref_engine = engine(123)
    k = 0
    for configs in cands:
        for times in seeds:
            assert_reports_identical(
                got[k], scalar_cell(ref_engine, template, configs, times))
            k += 1


class _NoClampBackend(AnalyticBackend):
    """Deterministic surface whose failures are unbounded (+inf): the
    run_many plane must serialize those candidates — a dead instance
    never runs its downstream nodes, which longest-path cannot see."""

    has_clamped = False

    def _surface(self, cpu, mem, spec_arrays):
        rt, failed = super()._surface(cpu, mem, spec_arrays)
        return np.where(failed, np.inf, rt), failed


def test_run_many_serializes_unbounded_failure_candidates():
    template = TOPOLOGIES["fan"]()
    healthy = {n.name: ResourceConfig(cpu=4.0, mem=8192.0)
               for n in template}
    dying = {n.name: ResourceConfig(cpu=4.0, mem=128.0)    # below floors
             for n in template}
    engine = FleetEngine(_NoClampBackend(),
                         pricing=SimulatedPlatform().pricing)
    reports = assert_grid_identical(engine, template, [healthy, dying],
                                    arrival_sets(2))
    assert not reports[0].failed_mask.any()
    assert reports[2].failed_mask.all()
    assert math.isinf(reports[2].p99)


def test_opaque_callable_backend_falls_back_and_matches():
    """Backends without a config-batch surface (bare oracles) keep the
    exact looped semantics."""
    template = TOPOLOGIES["chain"]()
    engine = FleetEngine(CallableBackend(lambda node: node.config.cpu * 0.1),
                         pricing=SimulatedPlatform().pricing)
    assert_grid_identical(engine, template,
                          candidate_sets(template, 2, seed=8),
                          arrival_sets(2))


def test_run_many_single_instance_cell_matches_degenerate_path():
    """A fleet of one goes through ``run``'s degenerate fast path,
    whose float associations differ from the absolute-time plane —
    run_many must serialize that cell to stay bit-identical. Uses a
    template whose insertion order differs from topological order so
    any accumulation-order divergence would surface."""
    from repro.core.dag import Workflow
    from repro.serverless.generator import random_spec

    rng = np.random.default_rng(5)
    template = Workflow("scrambled")
    for name in ("f2", "f0", "f1"):          # non-topological insertion
        template.add_function(name, payload=random_spec(name, rng))
    template.add_edge("f0", "f1")
    template.add_edge("f1", "f2")
    engine = make_engine()
    cands = candidate_sets(template, 2, seed=10)
    # nonzero arrival: the degenerate path computes e2e relative and
    # shifts by the arrival, unlike the absolute event-time chain
    assert_grid_identical(engine, template, cands,
                          [np.array([13.7])])


def test_custom_pricing_overrides_are_honored():
    """A pricing model that customizes only scalar function_cost must
    not be silently priced with the base mu-formula (neither by the
    admission rounds nor by the run_many plane)."""
    from repro.core.cost import PricingModel

    class DoubledPricing(PricingModel):
        def function_cost(self, runtime_s, config):
            return 2.0 * super().function_cost(runtime_s, config)

    template = TOPOLOGIES["chain"]()
    env = SimulatedPlatform().environment()
    base = FleetEngine(env.backend)
    doubled = FleetEngine(env.backend, pricing=DoubledPricing())
    assert not doubled._pricing_vectorized     # falls back to scalar
    cands = candidate_sets(template, 1, seed=11)
    times = arrival_sets(1)[0]
    got = doubled.run_many(template, cands, [times])[0]
    ref = base.run_many(template, cands, [times])[0]
    assert got.total_cost == pytest.approx(2.0 * ref.total_cost)
    # a custom *vectorized* implementation is trusted as-is
    class VectorizedDoubled(DoubledPricing):
        def cost_batch(self, runtime_s, cpu, mem):
            return 2.0 * super().cost_batch(runtime_s, cpu, mem)

    vec = FleetEngine(env.backend, pricing=VectorizedDoubled())
    assert vec._pricing_vectorized
    got_vec = vec.run_many(template, cands, [times])[0]
    assert got_vec.total_cost == pytest.approx(got.total_cost)


def test_online_stochastic_validation_stays_paired():
    """On a stochastic backend the challenger gate must remain a
    *paired* comparison: every candidate validated under identical
    noise draws. The same configuration in both slots must therefore
    score identically (a shared noise stream would break this)."""
    from repro.core.campaign import PortfolioSpec, ReplaySpec
    from repro.core.online import OnlineController, OnlineSpec
    from repro.serverless.generator import EpochConditions
    from repro.serverless.platform import make_env

    spec = OnlineSpec(
        portfolio=PortfolioSpec(n_workflows=1, size=4, slo_slacks=(2.0,)),
        replay=ReplaySpec(n_instances=6, rate=0.5), n_epochs=1)
    ctl = OnlineController(
        spec, env_factory=lambda: make_env(noise_sigma=0.05, seed=17))
    tasks = ctl._campaign.tasks()
    cells = ctl._deploy(tasks, ctl._campaign.arrival_seeds(len(tasks)))
    cond = EpochConditions()
    cfg = cells[0].configs
    a, b = ctl._validate_many(cells[0], [cfg, cfg], cond, seed=3)
    assert a == b


# -- SoA report memoization (accessor-waste satellite) -----------------

def test_report_accessors_are_memoized():
    template = TOPOLOGIES["chain"]()
    engine = make_engine(cluster=ClusterModel(total_cpu=12.0,
                                              total_mem_mb=16384.0))
    rep = scalar_cell(engine, template,
                      candidate_sets(template, 1, seed=9)[0],
                      PoissonArrivals(1.0, 8, seed=1).times())
    assert rep.latencies is rep.latencies            # no rebuild per call
    assert rep.instances is rep.instances
    assert rep.total_cost == rep.total_cost
    assert rep.total_cost == sum(r.cost for r in rep.instances)
    assert rep.total_queue_delay == \
        sum(r.queue_delay for r in rep.instances)
    assert rep.slo_attainment(5.0) == rep.slo_attainment(5.0)
    # object view agrees with the arrays
    for i, r in enumerate(rep.instances):
        assert r.uid == i
        assert r.e2e == rep.latencies[i]
        assert r.cost == rep.costs[i]
        assert r.failed == rep.failed_mask[i]


def test_report_legacy_instances_constructor_roundtrips():
    from repro.core.engine import FleetReport, InstanceResult

    rows = [InstanceResult(uid=0, arrival=0.0, finish=2.0, e2e=2.0,
                           queue_delay=0.5, cold_delay=0.0, cost=1.25,
                           failed=False),
            InstanceResult(uid=1, arrival=1.0, finish=math.inf, e2e=math.inf,
                           queue_delay=0.0, cold_delay=0.0, cost=0.0,
                           failed=True)]
    rep = FleetReport(instances=rows, makespan=2.0,
                      cpu_utilization=0.0, mem_utilization=0.0,
                      queue_delay_by_function={})
    assert rep.instances == rows
    assert np.array_equal(rep.latencies, [2.0, math.inf])
    assert rep.slo_attainment(3.0) == 0.5
    assert rep.total_cost == 1.25
    assert rep.p50 == math.inf or rep.p50 == 2.0   # interpolation defined
    assert not math.isnan(rep.p99)
