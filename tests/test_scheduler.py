"""Algorithm 1 (Graph-Centric Scheduler) end-to-end on the paper's
three workflows + the §IV-D input-aware plugin."""
import pytest

from repro.core.cost import workflow_cost
from repro.core.input_aware import InputAwareEngine
from repro.core.resources import BASE_CONFIG
from repro.core.scheduler import GraphCentricScheduler
from repro.serverless.platform import SimulatedPlatform, make_scaled_env
from repro.serverless.workloads import WORKLOADS, workload_slo


@pytest.mark.parametrize("name", list(WORKLOADS))
def test_schedule_meets_slo_and_cuts_cost(name):
    wf = WORKLOADS[name]()
    slo = workload_slo(name)
    env = SimulatedPlatform().environment()

    # base cost
    base_wf = WORKLOADS[name]()
    for node in base_wf:
        node.config = BASE_CONFIG.copy()
    base_e2e = base_wf.execute(env.oracle)
    base_cost = workflow_cost(env.pricing, base_wf)
    env.reset_trace()

    result = GraphCentricScheduler(env).schedule(wf, slo)
    assert result.e2e_runtime <= slo + 1e-9, "SLO violated"
    assert result.cost < base_cost, "no cost saving over base config"
    assert set(result.configs) == set(wf.nodes), "missing per-function config"
    assert base_e2e <= slo, "workload calibration: base must meet SLO"


@pytest.mark.parametrize("name", list(WORKLOADS))
def test_every_function_scheduled_once(name):
    wf = WORKLOADS[name]()
    env = SimulatedPlatform().environment()
    GraphCentricScheduler(env).schedule(wf, workload_slo(name))
    assert all(node.scheduled for node in wf)


def test_critical_path_first_then_subpaths():
    """Samples for the critical path appear before sub-path samples."""
    wf = WORKLOADS["chatbot"]()
    env = SimulatedPlatform().environment()
    result = GraphCentricScheduler(env).schedule(wf, 120.0)
    cp = set(result.critical_path)
    seen_subpath = False
    for s in env.trace.samples:
        if not s.note.startswith("aarc:") or s.note in ("aarc:base",
                                                        "aarc:final"):
            continue
        func = s.note.split(":")[1]
        if func in cp:
            assert not seen_subpath, "critical path configured after subpath"
        else:
            seen_subpath = True


def test_infeasible_slo_raises():
    wf = WORKLOADS["chatbot"]()
    env = SimulatedPlatform().environment()
    with pytest.raises(ValueError):
        GraphCentricScheduler(env).schedule(wf, slo=1.0)


def test_input_aware_plugin():
    """§IV-D: per-input-class tables; heavy inputs stay within SLO."""
    from repro.serverless.workloads import video_analysis
    slo = 600.0
    engine = InputAwareEngine(video_analysis, make_scaled_env, slo)
    engine.profile()
    assert set(engine.tables) == {"light", "middle", "heavy"}

    for cls_name, scale in (("light", 0.35), ("middle", 1.0),
                            ("heavy", 1.7)):
        cfgs = engine.dispatch({"scale": scale})
        wf = video_analysis()
        wf.apply_configs(cfgs)
        env = make_scaled_env(scale)
        e2e = wf.execute(env.oracle)
        assert e2e <= slo + 1e-9, f"{cls_name} violates SLO"

    # light configs must be cheaper than heavy configs on light input
    wf_l = video_analysis()
    wf_l.apply_configs(engine.tables["light"])
    env = make_scaled_env(0.35)
    wf_l.execute(env.oracle)
    light_cost = workflow_cost(env.pricing, wf_l)
    wf_h = video_analysis()
    wf_h.apply_configs(engine.tables["heavy"])
    wf_h.execute(env.oracle)
    heavy_cost = workflow_cost(env.pricing, wf_h)
    assert light_cost < heavy_cost
