"""Searcher-protocol conformance + batched-evaluation parity.

Pins the campaign-scale refactor's two invariants:

  * all three searchers satisfy :class:`repro.core.search.Searcher`
    and, at ``batch_size=1``, produce traces identical to their legacy
    entry points on the paper's three hand-built workloads,
  * batched candidate evaluation (``execute_batch`` /
    ``execute_candidates``) and batched Algorithm 2 agree with the
    scalar path on generated DAGs.
"""
import math

import pytest

from repro.core.baselines.bo import bo_search
from repro.core.baselines.maff import maff_search
from repro.core.cost import workflow_cost
from repro.core.priority import priority_configuration
from repro.core.resources import BASE_CONFIG, ResourceConfig
from repro.core.scheduler import GraphCentricScheduler
from repro.core.search import (SEARCHERS, Searcher, SearchResult,
                               make_searcher)
from repro.serverless.generator import layered_workflow, suggest_slo
from repro.serverless.platform import SimulatedPlatform, make_env
from repro.serverless.workloads import WORKLOADS, workload_slo


def _trace_rows(trace):
    return [(s.index, s.e2e_runtime, s.cost, s.feasible, s.error,
             s.trial_time, s.note, s.config_items)
            for s in trace.samples]


def _legacy_trace(method, name):
    wf = WORKLOADS[name]()
    slo = workload_slo(name)
    env = SimulatedPlatform().environment()
    if method == "aarc":
        GraphCentricScheduler(env).schedule(wf, slo)
    elif method == "maff":
        maff_search(wf, slo, env)
    else:
        bo_search(wf, slo, env, n_rounds=30, seed=0)
    return env.trace


# -- protocol conformance ----------------------------------------------

@pytest.mark.parametrize("name", sorted(SEARCHERS))
def test_registered_searchers_satisfy_protocol(name):
    searcher = make_searcher(name, make_env)
    assert isinstance(searcher, Searcher)
    assert searcher.name == name


def test_unknown_searcher_rejected():
    with pytest.raises(ValueError, match="unknown searcher"):
        make_searcher("simulated-annealing", make_env)


def test_duck_typed_searcher_satisfies_protocol():
    class Constant:
        name = "constant"

        def search(self, wf, slo):
            raise NotImplementedError

    assert isinstance(Constant(), Searcher)


@pytest.mark.parametrize("method", ["aarc", "bo", "maff"])
@pytest.mark.parametrize("workload", sorted(WORKLOADS))
def test_search_result_is_consistent(method, workload):
    kwargs = {"bo": {"n_rounds": 30, "seed": 0}}.get(method, {})
    res = make_searcher(method, make_env, **kwargs).search(
        WORKLOADS[workload](), workload_slo(workload))
    assert isinstance(res, SearchResult)
    assert res.searcher == method and res.workflow == workload
    assert res.feasible and res.e2e_runtime <= res.slo + 1e-9
    assert res.n_samples == res.trace.n_samples
    assert res.search_time == res.trace.total_search_runtime
    assert set(res.configs) == set(WORKLOADS[workload]().nodes)
    assert res.best is not None and res.best.cost <= res.cost + 1e-9


# -- trace parity vs the legacy entry points ---------------------------

@pytest.mark.parametrize("method", ["aarc", "bo", "maff"])
@pytest.mark.parametrize("workload", sorted(WORKLOADS))
def test_protocol_trace_identical_to_legacy(method, workload):
    """The Searcher wrappers add bookkeeping, not samples: traces are
    bit-for-bit the legacy entry points' traces at batch_size=1."""
    kwargs = {"bo": {"n_rounds": 30, "seed": 0}}.get(method, {})
    res = make_searcher(method, make_env, **kwargs).search(
        WORKLOADS[workload](), workload_slo(workload))
    assert _trace_rows(res.trace) == _trace_rows(_legacy_trace(method,
                                                               workload))


# -- batched candidate evaluation --------------------------------------

def _random_candidates(wf, n, seed):
    import numpy as np

    rng = np.random.default_rng(seed)
    return [
        {node.name: ResourceConfig(cpu=float(rng.uniform(0.5, 10.0)),
                                   mem=float(rng.uniform(256.0, 10240.0)))
         for node in wf}
        for _ in range(n)]


def test_execute_candidates_matches_scalar_execute():
    wf = layered_workflow(16, n_layers=4, seed=2)
    slo = suggest_slo(wf)
    cands = _random_candidates(wf, 12, seed=0)
    batched = make_env().execute_candidates(wf, cands, slo)
    env = make_env()
    for got, cand in zip(batched, cands):
        probe = wf.copy()
        probe.apply_configs(cand)
        want = env.execute(probe, slo)
        assert got.e2e_runtime == want.e2e_runtime
        assert got.cost == pytest.approx(want.cost, rel=1e-12)
        assert (got.feasible, got.error) == (want.feasible, want.error)
    # pure evaluation: the template workflow's configs are untouched
    assert all(n.config.as_tuple() == BASE_CONFIG.as_tuple() for n in wf)


def test_execute_batch_matches_scalar_execute():
    wfs = [layered_workflow(10, n_layers=3, seed=s) for s in range(4)]
    slos = [suggest_slo(w) for w in wfs]
    env_b = make_env()
    batched = env_b.execute_batch([w.copy() for w in wfs], slos)
    env_s = make_env()
    for wf, slo, got in zip(wfs, slos, batched):
        want = env_s.execute(wf.copy(), slo)
        assert got.e2e_runtime == want.e2e_runtime
        assert got.cost == want.cost
        assert got.feasible == want.feasible


def test_execute_batch_length_mismatch_rejected():
    env = make_env()
    with pytest.raises(ValueError, match="mismatch"):
        env.execute_batch([layered_workflow(4, seed=0)], [1.0, 2.0])


def test_execute_function_batch_commits_sequentially():
    """Sample i reflects trials 0..i applied (commit-all, no revert)."""
    def prepared():
        wf = layered_workflow(8, n_layers=2, seed=5)
        slo = suggest_slo(wf)
        env = make_env()
        env.execute(wf, slo)                 # populate runtimes
        nodes = [wf.nodes[n] for n in wf.topological_order()[:3]]
        for node in nodes:
            node.config = ResourceConfig(cpu=2.0, mem=4096.0)
        return wf, nodes, slo, env

    wf_b, nodes_b, slo, env_b = prepared()
    batched = env_b.execute_function_batch(wf_b, nodes_b, slo)
    wf_s, nodes_s, slo, env_s = prepared()
    scalar = [env_s.execute_function(wf_s, node, slo) for node in nodes_s]
    assert [s.e2e_runtime for s in batched] == [s.e2e_runtime for s in scalar]
    assert [s.cost for s in batched] == [s.cost for s in scalar]
    assert [s.trial_time for s in batched] == [s.trial_time for s in scalar]


def test_bo_and_maff_reject_capture_opt_out():
    """BO/MAFF read the winning configs back from the trace, so the
    compact-capture opt-out must fail loudly instead of returning
    empty configurations."""
    from repro.core.env import Environment
    from repro.serverless.platform import AnalyticBackend

    wf = WORKLOADS["chatbot"]()
    env = Environment(AnalyticBackend(), capture_configs=False)
    with pytest.raises(ValueError, match="capture_configs"):
        bo_search(wf, workload_slo("chatbot"), env, n_rounds=5)
    with pytest.raises(ValueError, match="capture_configs"):
        maff_search(wf, workload_slo("chatbot"), env)
    # AARC takes configs from the scheduler, not the trace — safe
    env = Environment(AnalyticBackend(), capture_configs=False)
    res = GraphCentricScheduler(env).schedule(wf, workload_slo("chatbot"))
    assert set(res.configs) == set(wf.nodes)


def test_bo_batched_rounds_consume_same_budget():
    wf = WORKLOADS["chatbot"]()
    res = make_searcher("bo", make_env, n_rounds=30, seed=0,
                        batch_size=8).search(wf, workload_slo("chatbot"))
    assert res.n_samples == 30
    assert res.feasible


# -- Algorithm 2: batched vs scalar parity on generated DAGs -----------

def _prepare(seed):
    """Base-configured layered DAG + its critical path (the path Alg 1
    actually feeds to Alg 2 — its latency equals the e2e latency, so
    the SLO leaves real slack and trials get accepted)."""
    from repro.core.critical_path import find_critical_path

    wf = layered_workflow(20, n_layers=4, seed=seed)
    env = SimulatedPlatform().environment()
    for node in wf:
        node.config = BASE_CONFIG.copy()
    base_e2e = wf.execute(env.oracle)
    return wf, env, find_critical_path(wf), base_e2e


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_priority_batch_size_one_is_scalar_path(seed):
    """batch_size=1 must be the untouched scalar loop, bit-for-bit."""
    wf_a, env_a, path_a, e2e_a = _prepare(seed)
    priority_configuration(wf_a, path_a, 1.5 * e2e_a, env_a, batch_size=1)
    wf_b, env_b, path_b, e2e_b = _prepare(seed)
    priority_configuration(wf_b, path_b, 1.5 * e2e_b, env_b)  # default path
    assert _trace_rows(env_a.trace) == _trace_rows(env_b.trace)
    accepted = [s for s in env_a.trace.samples if s.feasible]
    assert accepted, "no trial accepted — the comparison would be vacuous"
    assert workflow_cost(env_a.pricing, wf_a) == \
        workflow_cost(env_b.pricing, wf_b)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("batch_size", [4, 8])
def test_priority_batched_keeps_invariants(seed, batch_size):
    """Batched rounds keep Alg 2's guarantees: SLO respected, cost
    strictly reduced from base, budget respected, revert-safe."""
    wf, env, path, base_e2e = _prepare(seed)
    base_cost = workflow_cost(env.pricing, wf)
    slo = 1.5 * base_e2e
    priority_configuration(wf, path, slo, env, batch_size=batch_size)
    assert wf.end_to_end_latency() <= slo + 1e-9
    assert wf.path_latency(path) <= slo + 1e-9
    assert workflow_cost(env.pricing, wf) < base_cost, \
        "no deallocation accepted — batched search did nothing"
    assert env.trace.n_samples <= 64        # MAX_TRAIL
    for node in wf:
        assert not node.failed


@pytest.mark.parametrize("batch_size", [1, 4])
def test_scheduler_batched_meets_slo_on_paper_workloads(batch_size):
    for name in WORKLOADS:
        wf = WORKLOADS[name]()
        env = SimulatedPlatform().environment()
        res = GraphCentricScheduler(env, batch_size=batch_size).schedule(
            wf, workload_slo(name))
        assert res.e2e_runtime <= workload_slo(name) + 1e-9


# -- trace storage (compact capture) -----------------------------------

def test_sample_configs_reconstructed_from_compact_items():
    env = make_env()
    wf = WORKLOADS["chatbot"]()
    sample = env.execute(wf, slo=workload_slo("chatbot"))
    assert isinstance(sample.config_items, tuple)
    assert sample.configs == wf.configs()
    # reconstruction is on demand — items stay primitive tuples
    assert all(isinstance(item, tuple) and len(item) == 3
               for item in sample.config_items)


def test_trace_capture_opt_out():
    from repro.core.env import Environment
    from repro.serverless.platform import AnalyticBackend

    env = Environment(AnalyticBackend(), capture_configs=False)
    wf = WORKLOADS["chatbot"]()
    sample = env.execute(wf, slo=workload_slo("chatbot"))
    assert sample.config_items == () and sample.configs == {}
    env.reset_trace()
    assert env.trace.capture_configs is False


def test_environment_reuses_engine():
    env = make_env()
    wf = WORKLOADS["chatbot"]()
    env.execute(wf, slo=120.0)
    engine = env.engine
    env.execute(wf, slo=120.0)
    assert env.engine is engine
