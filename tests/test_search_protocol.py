"""Searcher-protocol conformance + batched-evaluation parity.

Pins the campaign-scale refactor's two invariants:

  * all three searchers satisfy :class:`repro.core.search.Searcher`
    and, at ``batch_size=1``, produce traces identical to their legacy
    entry points on the paper's three hand-built workloads,
  * batched candidate evaluation (``execute_batch`` /
    ``execute_candidates``) and batched Algorithm 2 agree with the
    scalar path on generated DAGs.
"""
import math

import pytest

from repro.core.baselines.bo import bo_search
from repro.core.baselines.maff import maff_search
from repro.core.cost import workflow_cost
from repro.core.priority import priority_configuration
from repro.core.resources import BASE_CONFIG, ResourceConfig
from repro.core.scheduler import GraphCentricScheduler
from repro.core.search import (SEARCHERS, Searcher, SearchResult,
                               make_searcher)
from repro.serverless.generator import layered_workflow, suggest_slo
from repro.serverless.platform import SimulatedPlatform, make_env
from repro.serverless.workloads import WORKLOADS, workload_slo


def _trace_rows(trace):
    return [(s.index, s.e2e_runtime, s.cost, s.feasible, s.error,
             s.trial_time, s.note, s.config_items)
            for s in trace.samples]


def _legacy_trace(method, name):
    wf = WORKLOADS[name]()
    slo = workload_slo(name)
    env = SimulatedPlatform().environment()
    if method == "aarc":
        GraphCentricScheduler(env).schedule(wf, slo)
    elif method == "maff":
        maff_search(wf, slo, env)
    else:
        bo_search(wf, slo, env, n_rounds=30, seed=0)
    return env.trace


# -- protocol conformance ----------------------------------------------

@pytest.mark.parametrize("name", sorted(SEARCHERS))
def test_registered_searchers_satisfy_protocol(name):
    searcher = make_searcher(name, make_env)
    assert isinstance(searcher, Searcher)
    assert searcher.name == name


def test_unknown_searcher_rejected():
    with pytest.raises(ValueError, match="unknown searcher"):
        make_searcher("simulated-annealing", make_env)


def test_duck_typed_searcher_satisfies_protocol():
    class Constant:
        name = "constant"

        def search(self, wf, slo):
            raise NotImplementedError

        def resume(self, state, extra_budget):
            raise NotImplementedError

    assert isinstance(Constant(), Searcher)


def test_search_without_resume_no_longer_satisfies_protocol():
    class Legacy:
        name = "legacy"

        def search(self, wf, slo):
            raise NotImplementedError

    assert not isinstance(Legacy(), Searcher)


@pytest.mark.parametrize("method", ["aarc", "bo", "maff"])
@pytest.mark.parametrize("workload", sorted(WORKLOADS))
def test_search_result_is_consistent(method, workload):
    kwargs = {"bo": {"n_rounds": 30, "seed": 0}}.get(method, {})
    res = make_searcher(method, make_env, **kwargs).search(
        WORKLOADS[workload](), workload_slo(workload))
    assert isinstance(res, SearchResult)
    assert res.searcher == method and res.workflow == workload
    assert res.feasible and res.e2e_runtime <= res.slo + 1e-9
    assert res.n_samples == res.trace.n_samples
    assert res.search_time == res.trace.total_search_runtime
    assert set(res.configs) == set(WORKLOADS[workload]().nodes)
    assert res.best is not None and res.best.cost <= res.cost + 1e-9


# -- trace parity vs the legacy entry points ---------------------------

@pytest.mark.parametrize("method", ["aarc", "bo", "maff"])
@pytest.mark.parametrize("workload", sorted(WORKLOADS))
def test_protocol_trace_identical_to_legacy(method, workload):
    """The Searcher wrappers add bookkeeping, not samples: traces are
    bit-for-bit the legacy entry points' traces at batch_size=1."""
    kwargs = {"bo": {"n_rounds": 30, "seed": 0}}.get(method, {})
    res = make_searcher(method, make_env, **kwargs).search(
        WORKLOADS[workload](), workload_slo(workload))
    assert _trace_rows(res.trace) == _trace_rows(_legacy_trace(method,
                                                               workload))


# -- batched candidate evaluation --------------------------------------

def _random_candidates(wf, n, seed):
    import numpy as np

    rng = np.random.default_rng(seed)
    return [
        {node.name: ResourceConfig(cpu=float(rng.uniform(0.5, 10.0)),
                                   mem=float(rng.uniform(256.0, 10240.0)))
         for node in wf}
        for _ in range(n)]


def test_execute_candidates_matches_scalar_execute():
    wf = layered_workflow(16, n_layers=4, seed=2)
    slo = suggest_slo(wf)
    cands = _random_candidates(wf, 12, seed=0)
    batched = make_env().execute_candidates(wf, cands, slo)
    env = make_env()
    for got, cand in zip(batched, cands):
        probe = wf.copy()
        probe.apply_configs(cand)
        want = env.execute(probe, slo)
        assert got.e2e_runtime == want.e2e_runtime
        assert got.cost == pytest.approx(want.cost, rel=1e-12)
        assert (got.feasible, got.error) == (want.feasible, want.error)
    # pure evaluation: the template workflow's configs are untouched
    assert all(n.config.as_tuple() == BASE_CONFIG.as_tuple() for n in wf)


def test_execute_batch_matches_scalar_execute():
    wfs = [layered_workflow(10, n_layers=3, seed=s) for s in range(4)]
    slos = [suggest_slo(w) for w in wfs]
    env_b = make_env()
    batched = env_b.execute_batch([w.copy() for w in wfs], slos)
    env_s = make_env()
    for wf, slo, got in zip(wfs, slos, batched):
        want = env_s.execute(wf.copy(), slo)
        assert got.e2e_runtime == want.e2e_runtime
        assert got.cost == want.cost
        assert got.feasible == want.feasible


def test_execute_batch_length_mismatch_rejected():
    env = make_env()
    with pytest.raises(ValueError, match="mismatch"):
        env.execute_batch([layered_workflow(4, seed=0)], [1.0, 2.0])


def test_execute_function_batch_commits_sequentially():
    """Sample i reflects trials 0..i applied (commit-all, no revert)."""
    def prepared():
        wf = layered_workflow(8, n_layers=2, seed=5)
        slo = suggest_slo(wf)
        env = make_env()
        env.execute(wf, slo)                 # populate runtimes
        nodes = [wf.nodes[n] for n in wf.topological_order()[:3]]
        for node in nodes:
            node.config = ResourceConfig(cpu=2.0, mem=4096.0)
        return wf, nodes, slo, env

    wf_b, nodes_b, slo, env_b = prepared()
    batched = env_b.execute_function_batch(wf_b, nodes_b, slo)
    wf_s, nodes_s, slo, env_s = prepared()
    scalar = [env_s.execute_function(wf_s, node, slo) for node in nodes_s]
    assert [s.e2e_runtime for s in batched] == [s.e2e_runtime for s in scalar]
    assert [s.cost for s in batched] == [s.cost for s in scalar]
    assert [s.trial_time for s in batched] == [s.trial_time for s in scalar]


def test_bo_and_maff_reject_capture_opt_out():
    """BO/MAFF read the winning configs back from the trace, so the
    compact-capture opt-out must fail loudly instead of returning
    empty configurations."""
    from repro.core.env import Environment
    from repro.serverless.platform import AnalyticBackend

    wf = WORKLOADS["chatbot"]()
    env = Environment(AnalyticBackend(), capture_configs=False)
    with pytest.raises(ValueError, match="capture_configs"):
        bo_search(wf, workload_slo("chatbot"), env, n_rounds=5)
    with pytest.raises(ValueError, match="capture_configs"):
        maff_search(wf, workload_slo("chatbot"), env)
    # AARC takes configs from the scheduler, not the trace — safe
    env = Environment(AnalyticBackend(), capture_configs=False)
    res = GraphCentricScheduler(env).schedule(wf, workload_slo("chatbot"))
    assert set(res.configs) == set(wf.nodes)


def test_bo_batched_rounds_consume_same_budget():
    wf = WORKLOADS["chatbot"]()
    res = make_searcher("bo", make_env, n_rounds=30, seed=0,
                        batch_size=8).search(wf, workload_slo("chatbot"))
    assert res.n_samples == 30
    assert res.feasible


# -- Algorithm 2: batched vs scalar parity on generated DAGs -----------

def _prepare(seed):
    """Base-configured layered DAG + its critical path (the path Alg 1
    actually feeds to Alg 2 — its latency equals the e2e latency, so
    the SLO leaves real slack and trials get accepted)."""
    from repro.core.critical_path import find_critical_path

    wf = layered_workflow(20, n_layers=4, seed=seed)
    env = SimulatedPlatform().environment()
    for node in wf:
        node.config = BASE_CONFIG.copy()
    base_e2e = wf.execute(env.oracle)
    return wf, env, find_critical_path(wf), base_e2e


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_priority_batch_size_one_is_scalar_path(seed):
    """batch_size=1 must be the untouched scalar loop, bit-for-bit."""
    wf_a, env_a, path_a, e2e_a = _prepare(seed)
    priority_configuration(wf_a, path_a, 1.5 * e2e_a, env_a, batch_size=1)
    wf_b, env_b, path_b, e2e_b = _prepare(seed)
    priority_configuration(wf_b, path_b, 1.5 * e2e_b, env_b)  # default path
    assert _trace_rows(env_a.trace) == _trace_rows(env_b.trace)
    accepted = [s for s in env_a.trace.samples if s.feasible]
    assert accepted, "no trial accepted — the comparison would be vacuous"
    assert workflow_cost(env_a.pricing, wf_a) == \
        workflow_cost(env_b.pricing, wf_b)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("batch_size", [4, 8])
def test_priority_batched_keeps_invariants(seed, batch_size):
    """Batched rounds keep Alg 2's guarantees: SLO respected, cost
    strictly reduced from base, budget respected, revert-safe."""
    wf, env, path, base_e2e = _prepare(seed)
    base_cost = workflow_cost(env.pricing, wf)
    slo = 1.5 * base_e2e
    priority_configuration(wf, path, slo, env, batch_size=batch_size)
    assert wf.end_to_end_latency() <= slo + 1e-9
    assert wf.path_latency(path) <= slo + 1e-9
    assert workflow_cost(env.pricing, wf) < base_cost, \
        "no deallocation accepted — batched search did nothing"
    assert env.trace.n_samples <= 64        # MAX_TRAIL
    for node in wf:
        assert not node.failed


@pytest.mark.parametrize("batch_size", [1, 4])
def test_scheduler_batched_meets_slo_on_paper_workloads(batch_size):
    for name in WORKLOADS:
        wf = WORKLOADS[name]()
        env = SimulatedPlatform().environment()
        res = GraphCentricScheduler(env, batch_size=batch_size).schedule(
            wf, workload_slo(name))
        assert res.e2e_runtime <= workload_slo(name) + 1e-9


# -- trace storage (compact capture) -----------------------------------

def test_sample_configs_reconstructed_from_compact_items():
    env = make_env()
    wf = WORKLOADS["chatbot"]()
    sample = env.execute(wf, slo=workload_slo("chatbot"))
    assert isinstance(sample.config_items, tuple)
    assert sample.configs == wf.configs()
    # reconstruction is on demand — items stay primitive tuples
    assert all(isinstance(item, tuple) and len(item) == 3
               for item in sample.config_items)


def test_trace_capture_opt_out():
    from repro.core.env import Environment
    from repro.serverless.platform import AnalyticBackend

    env = Environment(AnalyticBackend(), capture_configs=False)
    wf = WORKLOADS["chatbot"]()
    sample = env.execute(wf, slo=workload_slo("chatbot"))
    assert sample.config_items == () and sample.configs == {}
    env.reset_trace()
    assert env.trace.capture_configs is False


def test_environment_reuses_engine():
    env = make_env()
    wf = WORKLOADS["chatbot"]()
    env.execute(wf, slo=120.0)
    engine = env.engine
    env.execute(wf, slo=120.0)
    assert env.engine is engine


# -- candidate validation (clear errors, not shape errors) --------------

def test_execute_candidates_rejects_unknown_function_names():
    """A candidate referencing functions absent from the workflow must
    fail with a diagnostic ValueError, not an opaque KeyError/shape
    error deep in the vectorized path."""
    wf = layered_workflow(6, n_layers=2, seed=1)
    good = {n.name: ResourceConfig(cpu=2.0, mem=2048.0) for n in wf}
    env = make_env()

    renamed = dict(good)
    renamed["not-a-function"] = renamed.pop(next(iter(good)))
    with pytest.raises(ValueError, match="unknown function.*not-a-function"):
        env.execute_candidates(wf, [good, renamed], slo=100.0)

    missing = dict(good)
    dropped = sorted(good)[0]
    del missing[dropped]
    with pytest.raises(ValueError, match=f"missing config.*{dropped}"):
        env.execute_candidates(wf, [missing], slo=100.0)
    # nothing was recorded for the failed batch
    assert env.trace.n_samples == 0


# -- resumable searches (Searcher.resume) -------------------------------

RESUME_KWARGS = {"aarc": {"max_trail": 8},
                 "bo": {"n_rounds": 10, "seed": 0},
                 "maff": {"max_samples": 10}}


@pytest.mark.parametrize("method", sorted(RESUME_KWARGS))
def test_resume_zero_budget_is_noop(method):
    wf = layered_workflow(10, n_layers=3, seed=4)
    slo = suggest_slo(wf)
    searcher = make_searcher(method, make_env, **RESUME_KWARGS[method])
    res = searcher.search(wf.copy(), slo)
    assert res.state is not None
    again = searcher.resume(res.state, 0)
    assert again is res
    assert again.n_samples == res.n_samples == res.trace.n_samples


@pytest.mark.parametrize("method", sorted(RESUME_KWARGS))
def test_resume_spends_at_most_the_extra_budget(method):
    wf = layered_workflow(10, n_layers=3, seed=4)
    slo = suggest_slo(wf)
    searcher = make_searcher(method, make_env, **RESUME_KWARGS[method])
    res = searcher.search(wf.copy(), slo)
    resumed = searcher.resume(res.state, 12)
    assert resumed.n_samples - res.n_samples <= 12
    assert resumed.n_samples == resumed.trace.n_samples
    # the cumulative result is never worse than what it resumed from
    assert resumed.feasible >= res.feasible
    assert resumed.cost <= res.cost + 1e-9
    twice = searcher.resume(resumed.state, 12)
    assert twice.n_samples - resumed.n_samples <= 12
    assert twice.cost <= resumed.cost + 1e-9


def test_resume_on_infeasible_aarc_declines_the_grant():
    """An SLO unreachable at the over-provisioned base config cannot be
    rescued by budget on a deterministic backend — resume must return
    the same result without sampling."""
    wf = layered_workflow(8, n_layers=2, seed=0)
    searcher = make_searcher("aarc", make_env)
    res = searcher.search(wf.copy(), slo=1e-6)
    assert not res.feasible
    resumed = searcher.resume(res.state, 16)
    assert resumed is res


# -- cross-searcher warm starts -----------------------------------------

def test_warm_started_bo_with_empty_trace_is_cold_bo():
    """warm_start=() / init_points=() must be the cold optimizer
    bit-for-bit — the PR 2 trace pin extended to the warm-start path."""
    wf = WORKLOADS["chatbot"]()
    slo = workload_slo("chatbot")
    cold = make_searcher("bo", make_env, n_rounds=30, seed=0).search(
        wf.copy(), slo)
    warm = make_searcher("bo", make_env, n_rounds=30, seed=0,
                         warm_start=(), init_points=()).search(wf.copy(), slo)
    assert _trace_rows(warm.trace) == _trace_rows(cold.trace)
    assert _trace_rows(warm.trace) == _trace_rows(_legacy_trace("bo",
                                                                "chatbot"))


@pytest.mark.parametrize("batch_size", [1, 4])
def test_warm_started_batch_bo_with_empty_trace_is_cold_bo(batch_size):
    wf = layered_workflow(10, n_layers=3, seed=7)
    slo = suggest_slo(wf)
    cold = make_searcher("bo", make_env, n_rounds=20, seed=5,
                         batch_size=batch_size).search(wf.copy(), slo)
    warm = make_searcher("bo", make_env, n_rounds=20, seed=5,
                         batch_size=batch_size, warm_start=[],
                         init_points=[]).search(wf.copy(), slo)
    assert _trace_rows(warm.trace) == _trace_rows(cold.trace)


def test_bo_warm_started_from_aarc_trace_starts_at_aarc_best():
    """AARC's accepted trials seed the GP for free (no budget) and the
    transferred incumbent is the first point evaluated, so a handful of
    rounds already match AARC's configuration cost."""
    wf = layered_workflow(10, n_layers=3, seed=4)
    slo = suggest_slo(wf)
    aarc = make_searcher("aarc", make_env).search(wf.copy(), slo)
    accepted = [s for s in aarc.trace.samples if s.feasible]
    warm = make_searcher("bo", make_env, n_rounds=5, seed=0,
                         warm_start=accepted,
                         init_points=[aarc.configs]).search(wf.copy(), slo)
    assert warm.feasible
    assert warm.n_samples == 5                  # warm data was free
    assert warm.cost <= aarc.cost + 1e-9
    first = warm.trace.samples[0]
    assert first.configs == aarc.configs


def test_maff_resume_budget_holds_on_stochastic_backend():
    """Resume reserves one sample for its re-anchoring base execution
    and disables the infeasible-start fallback, so even when stochastic
    noise makes the incumbent replay infeasible the grant is never
    overdrawn — and the incumbent is kept rather than discarded."""
    wf = layered_workflow(10, n_layers=3, seed=4)
    slo = suggest_slo(wf)
    for noise_seed in range(6):
        env_factory = lambda: make_env(noise_sigma=0.3, seed=noise_seed)
        searcher = make_searcher("maff", env_factory, max_samples=10)
        res = searcher.search(wf.copy(), slo)
        if not res.feasible:
            continue
        resumed = searcher.resume(res.state, 5)
        assert resumed.n_samples - res.n_samples <= 5
        assert resumed.feasible
        assert resumed.cost <= res.cost + 1e-9


def test_maff_warm_start_and_infeasible_start_fallback():
    wf = layered_workflow(10, n_layers=3, seed=4)
    slo = suggest_slo(wf)
    aarc = make_searcher("aarc", make_env).search(wf.copy(), slo)
    warm = make_searcher("maff", make_env, max_samples=10,
                         start_configs=aarc.configs).search(wf.copy(), slo)
    assert warm.feasible and warm.cost <= aarc.cost + 1e-9

    # a start violating the SLO falls back to the coupled base instead
    # of aborting the whole search
    bad_start = {n.name: ResourceConfig(cpu=0.1, mem=10240.0) for n in wf}
    fallback = make_searcher("maff", make_env, max_samples=10,
                             start_configs=bad_start).search(wf.copy(), slo)
    assert fallback.feasible
    assert fallback.trace.samples[1].note == "maff:base"
