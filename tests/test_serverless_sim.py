"""Serverless simulator: response-surface properties + calibration."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.env import ExecutionError
from repro.core.resources import ResourceConfig, coupled_config
from repro.serverless.function import FunctionSpec
from repro.serverless.platform import SimulatedPlatform
from repro.serverless.workloads import (WORKLOADS, chatbot, ml_pipeline,
                                        video_analysis, workload_slo)

SPEC = FunctionSpec("f", cpu_work=20.0, parallel_frac=0.8, mem_floor=512,
                    mem_knee=1024, mem_penalty=3.0, io_time=1.0)


@given(st.floats(0.1, 10.0), st.floats(0.1, 10.0))
@settings(max_examples=50, deadline=None)
def test_runtime_monotone_in_cpu(c1, c2):
    lo, hi = sorted((c1, c2))
    cfg_lo = ResourceConfig(cpu=lo, mem=2048)
    cfg_hi = ResourceConfig(cpu=hi, mem=2048)
    assert SPEC.runtime(cfg_hi) <= SPEC.runtime(cfg_lo) + 1e-9


@given(st.floats(512, 10240), st.floats(512, 10240))
@settings(max_examples=50, deadline=None)
def test_runtime_monotone_in_mem(m1, m2):
    lo, hi = sorted((m1, m2))
    assert SPEC.runtime(ResourceConfig(cpu=2, mem=hi)) <= \
        SPEC.runtime(ResourceConfig(cpu=2, mem=lo)) + 1e-9


def test_oom_below_floor():
    with pytest.raises(ExecutionError):
        SPEC.runtime(ResourceConfig(cpu=2, mem=256))


def test_memory_flat_above_knee():
    """Fig. 2a/2b: runtime unchanged as memory varies above the knee."""
    r1 = SPEC.runtime(ResourceConfig(cpu=2, mem=1024))
    r2 = SPEC.runtime(ResourceConfig(cpu=2, mem=10240))
    assert r1 == pytest.approx(r2)


def test_input_scale_grows_work_and_floor():
    cfg = ResourceConfig(cpu=2, mem=2048)
    assert SPEC.runtime(cfg, input_scale=2.0) > SPEC.runtime(cfg)
    with pytest.raises(ExecutionError):
        SPEC.runtime(ResourceConfig(cpu=2, mem=600), input_scale=2.0)


def test_clamped_runtime_finite_and_slower():
    bad = ResourceConfig(cpu=2, mem=256)
    good = ResourceConfig(cpu=2, mem=2048)
    rc = SPEC.runtime_clamped(bad)
    assert math.isfinite(rc) and rc > SPEC.runtime(good)


def test_stochastic_mode_reproducible():
    p1 = SimulatedPlatform(noise_sigma=0.025, seed=7)
    p2 = SimulatedPlatform(noise_sigma=0.025, seed=7)
    wf1, wf2 = chatbot(), chatbot()
    r1 = wf1.execute(p1.oracle)
    r2 = wf2.execute(p2.oracle)
    assert r1 == pytest.approx(r2)


@pytest.mark.parametrize("name", list(WORKLOADS))
def test_base_config_meets_slo(name):
    """Calibration: the over-provisioned base config must satisfy the
    paper's SLOs (120/120/600 s) — Algorithm 1's precondition."""
    wf = WORKLOADS[name]()
    env = SimulatedPlatform().environment()
    e2e = wf.execute(env.oracle)
    assert e2e <= workload_slo(name)


def test_decoupling_beats_coupling_on_ml_pipeline():
    """§II-A: the decoupled optimum for the CPU-heavy / memory-light
    ML Pipeline is cheaper than ANY coupled configuration."""
    from repro.core.cost import workflow_cost
    env = SimulatedPlatform().environment()

    def cost_at(cfg_fn):
        wf = ml_pipeline()
        for node in wf:
            node.config = cfg_fn()
        try:
            e2e = wf.execute(env.oracle)
        except ExecutionError:
            return float("inf"), float("inf")
        return e2e, workflow_cost(env.pricing, wf)

    # decoupled point from the paper: 4 vCPU + 512 MB
    e2e_d, cost_d = cost_at(lambda: ResourceConfig(cpu=4, mem=512))
    assert e2e_d <= 120.0
    best_coupled = float("inf")
    for mem in range(512, 10241, 512):
        e2e_c, cost_c = cost_at(lambda m=mem: coupled_config(m))
        if e2e_c <= 120.0:
            best_coupled = min(best_coupled, cost_c)
    assert cost_d < best_coupled, (
        f"decoupled {cost_d:.1f} vs best coupled {best_coupled:.1f}")
