"""Serving correctness: prefill+decode == full forward, and the
continuous-batching engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS
from repro.configs.registry import reduced_config
from repro.models.model import Model
from repro.serving import RequestQueue, ServeEngine

#: decode tolerance: fp32 reduced configs, small accumulation drift in
#: recurrent caches is expected
ATOL, RTOL = 2e-3, 2e-2


def extras_for(cfg, b):
    key = jax.random.key(42)
    out = {}
    if cfg.family == "audio":
        out["frames"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model), cfg.jdtype)
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model), cfg.jdtype)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """logits(prefill k) + logits(decode k+1..n) == forward(n) —
    the cache path is numerically the training path.

    MoE archs use a drop-free capacity factor here: capacity-based
    token dropping legitimately differs between a 32-token forward and
    a 2-token decode batch (documented MoE semantics), and this test
    targets the *cache* path, not router drop policy.
    """
    import dataclasses
    cfg = reduced_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    b, k, n = 2, 12, 16
    tokens = jax.random.randint(jax.random.key(1), (b, n), 0, cfg.vocab)
    extras = extras_for(cfg, b)

    full_logits, _ = model.forward(params,
                                   {"tokens": tokens, "labels": tokens,
                                    **extras})

    pre_logits, cache = model.prefill(
        params, {"tokens": tokens[:, :k], **extras}, max_len=n + 4)
    np.testing.assert_allclose(np.asarray(pre_logits[:, 0]),
                               np.asarray(full_logits[:, k - 1]),
                               atol=ATOL, rtol=RTOL)
    for i in range(k, n):
        step_logits, cache = model.decode_step(params, cache,
                                               tokens[:, i:i + 1])
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, i]),
            atol=ATOL, rtol=RTOL,
            err_msg=f"{arch}: decode step {i} diverges from forward")


def test_engine_continuous_batching_refills_slots():
    cfg = reduced_config("olmo-1b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, n_slots=2, max_len=48)
    q = RequestQueue()
    rng = np.random.default_rng(0)
    reqs = [q.submit(rng.integers(0, cfg.vocab, size=6), max_new_tokens=5)
            for _ in range(5)]
    results = eng.run(q)
    assert len(results) == 5
    assert all(len(r.tokens) == 5 for r in results)
    assert sorted(r.uid for r in results) == [r.uid for r in reqs]


def test_engine_honors_timed_arrivals():
    """With a step clock, requests stamped by an arrival process are
    only admitted once they have arrived (shared fleet-engine traffic
    models drive LLM serving too)."""
    cfg = reduced_config("olmo-1b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, n_slots=2, max_len=48)
    q = RequestQueue()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=6) for _ in range(3)]
    # arrivals at t=0 and far beyond the first request's decode window
    reqs = q.submit_process([0.0, 50.0, 50.0], prompts, max_new_tokens=4)
    results = eng.run(q, step_duration_s=1.0)
    assert sorted(r.uid for r in results) == sorted(r.uid for r in reqs)
    assert all(len(r.tokens) == 4 for r in results)
    # ignoring the clock admits everything immediately and still drains
    q2 = RequestQueue()
    q2.submit_process([0.0, 50.0], prompts[:2], max_new_tokens=4)
    eng2 = ServeEngine(model, params, n_slots=2, max_len=48)
    assert len(eng2.run(q2)) == 2


def test_queue_orders_out_of_order_arrivals():
    """An already-arrived request must not be blocked behind a
    later-arriving one submitted first."""
    q = RequestQueue()
    late = q.submit(np.asarray([1, 2], np.int32), arrival=100.0)
    early = q.submit(np.asarray([3, 4], np.int32), arrival=0.0)
    assert q.next_arrival() == 0.0
    assert q.pop(now=0.0).uid == early.uid
    assert q.pop(now=0.0) is None          # late one hasn't arrived
    assert q.pop(now=100.0).uid == late.uid
    # equal arrivals keep FIFO order
    q2 = RequestQueue()
    a = q2.submit(np.asarray([1], np.int32), arrival=5.0)
    b = q2.submit(np.asarray([2], np.int32), arrival=5.0)
    assert q2.pop(now=5.0).uid == a.uid
    assert q2.pop(now=5.0).uid == b.uid


def test_engine_rejects_nonpositive_step_duration():
    cfg = reduced_config("olmo-1b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, n_slots=1, max_len=16)
    q = RequestQueue()
    q.submit(np.asarray([1, 2], np.int32), arrival=1.0)
    with pytest.raises(ValueError, match="step_duration_s"):
        eng.run(q, step_duration_s=0.0)


def test_engine_greedy_matches_manual_decode():
    """Engine slot path reproduces a manual prefill+argmax loop."""
    cfg = reduced_config("qwen3-0.6b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    prompt = np.asarray([5, 9, 2, 7], np.int32)

    # manual
    logits, cache = model.prefill(params,
                                  {"tokens": jnp.asarray(prompt)[None]},
                                  max_len=32)
    manual = []
    tok = int(jnp.argmax(logits[0, -1]))
    manual.append(tok)
    for _ in range(4):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[tok]], jnp.int32))
        tok = int(jnp.argmax(logits[0, 0]))
        manual.append(tok)

    eng = ServeEngine(model, params, n_slots=1, max_len=32)
    q = RequestQueue()
    q.submit(prompt, max_new_tokens=5)
    (res,) = eng.run(q)
    assert res.tokens == manual
