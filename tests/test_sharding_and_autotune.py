"""Sharding-rule derivation + the AARC-on-TPU autotuner."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import SHAPES, get_config
from repro.autotune import build_stage_graph, make_tpu_env, plan
from repro.autotune.oracle import OracleConfig, TPUStageOracle
from repro.core.critical_path import find_critical_path
from repro.distributed.sharding import FSDP_RULES, TP_RULES, ShardingRules


class FakeMesh:
    """Duck-typed mesh: only .shape is consulted by the rules."""

    def __init__(self, **shape):
        self.shape = shape


def test_spec_divisibility_fallback():
    mesh = FakeMesh(data=16, model=16)
    # 40 experts don't divide 16 -> replicated; mlp dim shards
    spec = FSDP_RULES.spec(("expert", "embed", "mlp"), (40, 1536, 512),
                           mesh)
    assert spec == __import__("jax").sharding.PartitionSpec(
        None, "data", "model")


def test_spec_never_reuses_mesh_axis():
    mesh = FakeMesh(data=16, model=16)
    spec = FSDP_RULES.spec(("mlp", "qkv"), (512, 512), mesh)
    parts = [p for p in spec if p is not None]
    flat = []
    for p in parts:
        flat.extend(p if isinstance(p, tuple) else [p])
    assert len(flat) == len(set(flat)), f"axis reused: {spec}"


def test_missing_mesh_axes_ignored():
    mesh = FakeMesh(data=4)               # no 'model', no 'pod'
    spec = FSDP_RULES.spec(("batch", "mlp"), (8, 512), mesh)
    assert spec == __import__("jax").sharding.PartitionSpec("data")


@given(st.integers(1, 64), st.integers(1, 64),
       st.sampled_from([("batch", None), ("embed", "mlp"),
                        ("vocab", "embed"), ("expert", "embed", "mlp")]))
@settings(max_examples=80, deadline=None)
def test_spec_property_divides(d0, d1, axes):
    mesh = FakeMesh(pod=2, data=16, model=16)
    shape = tuple([d0, d1] + [128] * (len(axes) - 2))
    spec = FSDP_RULES.spec(axes, shape, mesh)
    # every sharded dim must be divisible by the product of its axes
    for dim, part in zip(shape, tuple(spec) + (None,) * len(shape)):
        if part is None:
            continue
        axes_t = part if isinstance(part, tuple) else (part,)
        prod = 1
        for a in axes_t:
            prod *= mesh.shape[a]
        assert dim % prod == 0


# -- autotuner ---------------------------------------------------------------

def test_stage_graph_is_dag_with_full_coverage():
    cfg = get_config("whisper-tiny")
    wf = build_stage_graph(cfg, SHAPES["train_4k"])
    order = wf.topological_order()
    assert order[0] in ("embed", "encoder")
    assert "optimizer" in order
    # encoder branch exists and rejoins before the decoder layers
    cp_free = wf.successors("encoder")
    assert cp_free, "whisper encoder must feed the decoder stages"


def test_oracle_physics():
    """More chips -> faster (to a point); less memory -> slower/OOM."""
    from repro.core.dag import Node
    from repro.core.resources import ResourceConfig
    from repro.autotune.stages import StageSpec
    oracle = TPUStageOracle()
    spec = StageSpec("s", flops=1e15, param_bytes=60e9, act_bytes=120e9)

    def rt(cpu, mem):
        return oracle.runtime(Node("s", config=ResourceConfig(cpu=cpu,
                                                              mem=mem),
                                   payload=spec))

    assert rt(10, 10240) < rt(1, 10240)
    assert rt(10, 10240) < rt(10, 2048)       # remat penalty
    from repro.core.env import ExecutionError
    with pytest.raises(ExecutionError):
        rt(0.1, 128)                          # 60 GB of params on 3 chips


@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2-moe-a2.7b"])
def test_planner_slo_and_cost_ordering(arch):
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    # SLO with headroom above the base-config (all-resources) step time
    base = plan(cfg, shape, 1e9, method="aarc", max_trail=0).step_time
    slo = 2.0 * base
    r_aarc = plan(cfg, shape, slo, method="aarc")
    r_maff = plan(cfg, shape, slo, method="maff")
    assert r_aarc.step_time <= slo + 1e-9
    assert r_maff.step_time <= slo + 1e-9
    assert r_aarc.cost < r_maff.cost, (r_aarc.cost, r_maff.cost)
    # plans are actionable: every stage got chips + a remat level
    for name, sp in r_aarc.stages.items():
        assert sp.chips >= 1
        assert sp.remat in ("none", "dots", "full")


def test_planner_search_cheaper_than_bo():
    cfg = get_config("olmo-1b")
    r_aarc = plan(cfg, SHAPES["train_4k"], 0.6, method="aarc")
    r_bo = plan(cfg, SHAPES["train_4k"], 0.6, method="bo", max_trail=40)
    assert r_aarc.search_runtime < r_bo.search_runtime
