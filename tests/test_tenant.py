"""Tenant identity end-to-end: warm-pool keying (this PR's headline
bugfix), per-tenant report slices, interference multipliers, campaign
cell disambiguation, cross-tenant capacity conservation."""
import math

import pytest

from repro.core.backend import CallableBackend
from repro.core.campaign import Campaign, CampaignSpec, PortfolioSpec
from repro.core.dag import Workflow
from repro.core.engine import (ClusterModel, ColdStartModel, FleetEngine,
                               FleetReport)
from repro.core.resources import ResourceConfig

CONST = CallableBackend(lambda node: 1.0)
COLD = ColdStartModel(delay_s=5.0, keep_alive_s=600.0)


def _svc(tenant, cpu=None, mem=None):
    """A one-function service named ``svc`` — the *name* collides by
    construction; only the tenant id distinguishes instances."""
    wf = Workflow("svc", tenant=tenant)
    cfg = (ResourceConfig(cpu=cpu, mem=mem)
           if cpu is not None else None)
    wf.add_function("f", config=cfg)
    return wf


# --------------------------------------------------------------------------
# the warm-pool identity regression
# --------------------------------------------------------------------------

def test_warm_pool_is_tenant_keyed_not_name_keyed():
    """THE regression pinned by this PR: two tenants serving the same
    template *name* must not share warm containers. The pool used to be
    keyed ``(wf.name, fn)`` — tenant B claimed tenant A's container
    (sized for A's configuration) and skipped its cold start. This test
    fails under that keying: B would report ``cold_delay == 0``."""
    engine = FleetEngine(CONST, cold_start=COLD)
    rep = engine.run([_svc("tenantA"), _svc("tenantB")], [0.0, 10.0])
    # A finishes (and deposits its container) at t=6, well before B
    # arrives — yet B must still pay its own cold start
    assert list(rep.cold_delays) == [5.0, 5.0]


def test_same_identity_still_reuses_warm_containers():
    """Same identity (``tenant=None`` ⇒ identity == name) keeps the
    reuse the keep-alive model promises — the fix scopes sharing, it
    does not disable it."""
    engine = FleetEngine(CONST, cold_start=COLD)
    rep = engine.run([_svc(None), _svc(None)], [0.0, 10.0])
    assert list(rep.cold_delays) == [5.0, 0.0]


# --------------------------------------------------------------------------
# per-tenant report slices
# --------------------------------------------------------------------------

def test_tenant_slices_partition_the_packed_report():
    engine = FleetEngine(CONST, cluster=ClusterModel(64.0, 64 * 1024.0))
    wfs = [_svc("A", 4.0, 2048.0), _svc("B", 4.0, 2048.0),
           _svc("A", 4.0, 2048.0), _svc("B", 4.0, 2048.0)]
    rep = engine.run(wfs, [0.0, 0.5, 1.0, 1.5])
    assert rep.tenants == ["A", "B", "A", "B"]
    parts = rep.by_tenant()
    assert list(parts) == ["A", "B"]           # first-appearance order
    assert sum(p.arrivals.size for p in parts.values()) == 4
    assert (sum(p.total_cost for p in parts.values())
            == pytest.approx(rep.total_cost))
    for tenant, part in parts.items():
        assert part.tenants == [tenant, tenant]
        # per-function queue ledger is filtered to the tenant's prefix
        assert all(k.startswith(tenant + "/")
                   for k in part.queue_delay_by_function)


def test_tenant_slice_requires_tagged_report():
    with pytest.raises(ValueError, match="no tenant tags"):
        FleetReport().tenant_slice("A")


# --------------------------------------------------------------------------
# interference multipliers (the placement -> engine coupling)
# --------------------------------------------------------------------------

def test_interference_multiplier_slows_and_bills_the_tenant():
    base = FleetEngine(CONST).run([_svc("A")], [0.0])
    slow = FleetEngine(CONST, interference={("A", "f"): 1.5}).run(
        [_svc("A")], [0.0])
    # untargeted tenant is untouched
    other = FleetEngine(CONST, interference={("B", "f"): 1.5}).run(
        [_svc("A")], [0.0])
    assert slow.latencies[0] == pytest.approx(1.5 * base.latencies[0])
    assert slow.total_cost == pytest.approx(1.5 * base.total_cost)
    assert other.latencies[0] == base.latencies[0]


def test_interference_validation_rejects_bad_multipliers():
    for bad in (0.0, -1.0, math.inf, math.nan):
        with pytest.raises(ValueError, match="finite and positive"):
            FleetEngine(CONST, interference={("A", "f"): bad})


# --------------------------------------------------------------------------
# cross-tenant capacity conservation
# --------------------------------------------------------------------------

class _AuditedEngine(FleetEngine):
    """Spies every admission round: the shared capacity ledger must
    never overdraw the cluster, whatever mix of tenants is queued."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self.rounds = 0

    def _start_pending(self, t, pending, state, warm, used_cpu,
                       used_mem, events, seq, per_fn_queue, *args,
                       **kwargs):
        cpu, mem = super()._start_pending(
            t, pending, state, warm, used_cpu, used_mem, events, seq,
            per_fn_queue, *args, **kwargs)
        self.rounds += 1
        assert cpu <= self.cluster.total_cpu + 1e-9
        assert mem <= self.cluster.total_mem_mb + 1e-9
        return cpu, mem


def test_cross_tenant_capacity_conservation():
    engine = _AuditedEngine(CONST, cluster=ClusterModel(8.0, 8192.0))
    wfs = [_svc(f"t{i % 3}", 4.0, 2048.0) for i in range(6)]
    rep = engine.run(wfs, [0.0] * 6)
    assert engine.rounds > 0
    # only two 4-vCPU functions fit at once: the burst must queue
    assert rep.total_queue_delay > 0.0
    assert rep.tenants == [f"t{i % 3}" for i in range(6)]


# --------------------------------------------------------------------------
# campaign cells sharing one engine
# --------------------------------------------------------------------------

def test_campaign_cell_tenants_are_grid_unique():
    """Generated names collide across the grid (same workflow at two
    SLO slacks); the campaign must hand every cell a template with a
    grid-unique tenant identity so packed engines never alias."""
    spec = CampaignSpec(portfolio=PortfolioSpec(
        n_workflows=3, size=4, kinds=("chain",), slo_slacks=(1.3, 1.8)))
    tasks = Campaign(spec).tasks()
    assert len(tasks) == 6
    names = [t.template.name for t in tasks]
    idents = [t.template.identity for t in tasks]
    assert len(set(names)) < len(names)          # names DO collide
    assert len(set(idents)) == len(idents)       # identities never do
    assert all(ident == f"cell{t.index}.{t.template.name}"
               for ident, t in zip(idents, tasks))
