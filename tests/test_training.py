"""Training substrate: optimizer behaviour, grad-accum equivalence,
short integration run with decreasing loss, deterministic data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.models.model import Model
from repro.training.data import SyntheticDataset
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      global_norm, schedule)
from repro.training.train_step import make_train_step


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1e-3,
                                                                  rel=1e-2)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(1e-4,
                                                                   rel=1e-2)


def test_adamw_converges_on_quadratic():
    """Minimize ||x - t||^2 — sanity that the update math is right."""
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=0,
                      total_steps=500, min_lr_ratio=1.0)
    for _ in range(300):
        g = {"x": 2 * (state["params"]["x"] - target)}
        state, _ = adamw_update(state, g, cfg)
    np.testing.assert_allclose(np.asarray(state["params"]["x"]),
                               np.asarray(target), atol=1e-2)


def test_grad_clipping_bounds_update():
    params = {"x": jnp.zeros(4)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=1e-2, grad_clip=1.0, weight_decay=0.0,
                      warmup_steps=0)
    huge = {"x": jnp.full(4, 1e6)}
    state, metrics = adamw_update(state, huge, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    # post-clip effective gradient has unit norm; first Adam step is
    # bounded by lr regardless
    assert float(jnp.abs(state["params"]["x"]).max()) <= 2e-2


def test_microbatch_grad_accum_matches_full_batch():
    """scan-accumulated grads == single-batch grads (same math)."""
    cfg = reduced_config("olmo-1b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=16, global_batch=8)
    batch = ds.batch_at(0)

    def loss_fn(p, b):
        return model.loss(p, b)[0]

    g_full = jax.grad(loss_fn)(params, batch)

    # manual 4-way accumulation
    mbs = jax.tree.map(lambda x: x.reshape(4, 2, *x.shape[1:]), batch)
    g_acc = jax.tree.map(jnp.zeros_like, params)
    for i in range(4):
        mb = jax.tree.map(lambda x: x[i], mbs)
        g = jax.grad(loss_fn)(params, mb)
        g_acc = jax.tree.map(jnp.add, g_acc, g)
    g_acc = jax.tree.map(lambda g: g / 4, g_acc)

    flat_f = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_full)])
    flat_a = jnp.concatenate([x.ravel() for x in jax.tree.leaves(g_acc)])
    np.testing.assert_allclose(np.asarray(flat_a), np.asarray(flat_f),
                               atol=1e-5, rtol=1e-4)


def test_train_step_microbatched_runs():
    cfg = reduced_config("olmo-1b")
    model = Model(cfg)
    state = adamw_init(model.init(jax.random.key(0)))
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=16, global_batch=8)
    step1 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                    microbatches=1))
    step4 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                    microbatches=4))
    s1, m1 = step1(state, ds.batch_at(0))
    s4, m4 = step4(state, ds.batch_at(0))
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)
    # resulting params agree (same effective gradient)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     s1["params"], s4["params"])
    assert max(jax.tree.leaves(d)) < 5e-3


def test_loss_decreases_over_50_steps():
    """Integration: memorize a tiny fixed batch."""
    cfg = reduced_config("olmo-1b", n_layers=2)
    model = Model(cfg)
    state = adamw_init(model.init(jax.random.key(0)))
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=16, global_batch=4)
    batch = ds.batch_at(0)
    step = jax.jit(make_train_step(
        model, AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)))
    losses = []
    for _ in range(50):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < 0.5 * losses[0], losses[::10]


def test_dataset_deterministic_and_host_sharded():
    ds = SyntheticDataset(vocab=100, seq_len=8, global_batch=8)
    b1 = ds.batch_at(3)
    b2 = ds.batch_at(3)
    assert bool((b1["tokens"] == b2["tokens"]).all())
    b3 = ds.batch_at(4)
    assert not bool((b1["tokens"] == b3["tokens"]).all())
    # host sharding: different hosts, different shards, same step
    h0 = ds.batch_at(3, host_index=0, host_count=2)
    h1 = ds.batch_at(3, host_index=1, host_count=2)
    assert h0["tokens"].shape[0] == 4
    assert not bool((h0["tokens"] == h1["tokens"]).all())
    # labels are next-token shifted
    assert bool((b1["labels"][:, :-1] == b1["tokens"][:, 1:]).all())
